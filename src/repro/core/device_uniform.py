"""Device-resident uniform temporal neighbor sampling.

``DeviceUniformSampler`` is the JAX twin of ``UniformSampler``: the
CSR-by-time adjacency lives on the accelerator, built with JAX segment ops
(one ``segment_sum`` for the per-node degree counts + a stable composite-key
sort), and sampling is a single jitted global ``searchsorted`` over the
fused ``(node, time-rank)`` key — the same vectorization trick the device
recency sampler's update uses (see ``core/device_sampler.py``), ported to
the static-adjacency case:

  * ``rank(t)`` maps raw timestamps through the unique-time table, so the
    composite key ``node * (num_times + 1) + rank(t)`` is immune to raw
    timestamp magnitude and globally sorted (the adjacency is node-major
    with times ascending within each node);
  * per query, the count of neighbors strictly before ``query_t`` is
    ``searchsorted(keys, seed * base + rank(query_t)) - indptr[seed]`` —
    one vectorized search for the whole (B,) seed batch, no per-seed loop;
  * K draws per seed are taken uniformly (with replacement) from that
    prefix with a counter-derived ``jax.random`` key, so epochs are
    reproducible and ``reset_state`` replays them.

``state_dict``/``load_state_dict`` speak the same canonical host-numpy
contract as the host sampler (``adj_nbr/adj_t/adj_e/indptr/counter``), so
checkpoints are interchangeable between the two — mirroring the
``RecencySampler``/``DeviceRecencySampler`` pairing, which makes the two
sampler families drop-in swappable inside ``RECIPE_TGB_LINK``.

**Multi-device sharding** (``mesh=`` + ``docs/sharding.md``): the CSR is
split on node boundaries over the mesh's node axis — by default shard
``s`` owns nodes ``[s*per, (s+1)*per)`` (``partition="rows"``); with
``partition="degree"`` the cuts fall at cumulative-degree quantiles
instead, equalizing per-shard edge counts on skewed graphs (see
``_shard_bounds``). Each shard holds exactly its nodes' adjacency slice,
padded to the max per-shard edge count with int32-max keys so the local
``searchsorted`` stays correct. The sharded build runs host-side
(``_host_csr``, a stable numpy sort bit-identical to the jitted build)
and each shard's slice is materialized directly on its device, so the
full adjacency never exists on any single device. ``sample`` runs through ``shard_map``:
each shard counts/gathers only for the seeds it owns and two ``psum``s
combine the results (valid-prefix lengths first — so the replicated
uniform draws see the same bounds as the single-device path — then the
gathered rows). Draws are bit-identical to the single-device sampler at
any shard count; ``state_dict`` always reassembles the canonical host CSR,
so checkpoints reshard across mesh sizes in both directions.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_sampler import as_int32
from repro.core.sampler import NeighborBlock, csr_from_state

_I32_MAX = np.int32(2**31 - 1)


@partial(jax.jit, static_argnames=("num_nodes",))
def _build(nodes, nbrs, times, eids, *, num_nodes: int):
    """Sort the doubled edge list into node-major/time-ascending CSR order
    and compute per-node extents with segment ops. Pure/jit."""
    m = nodes.shape[0]
    # Unique-time table (padded to fixed size with int32 max so searchsorted
    # stays correct for any in-range query).
    tvals = jnp.unique(times, size=m, fill_value=_I32_MAX)
    tranks = jnp.searchsorted(tvals, times).astype(jnp.int32)
    num_t = jnp.searchsorted(tvals, _I32_MAX).astype(jnp.int32)
    base = num_t + 1
    # Stable sort on the (node, time-rank) composite key: groups by node,
    # time-ascending within the node, original order on exact ties — the
    # same layout numpy's lexsort((times, nodes)) produces on the host.
    key = nodes * base + tranks
    order = jnp.argsort(key, stable=True)
    counts = jax.ops.segment_sum(jnp.ones(m, jnp.int32), nodes,
                                 num_segments=num_nodes)
    indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
    return {
        "adj_nbr": nbrs[order],
        "adj_t": times[order],
        "adj_e": eids[order],
        "adj_key": key[order],
        "indptr": indptr,
        "tvals": tvals,
        "base": base,
    }


@partial(jax.jit, static_argnames=("k",))
def _sample(adj, seeds, query_t, rng_key, *, k: int):
    """Uniform K-with-replacement draws from each seed's strict-past prefix.

    One global ``searchsorted`` on the composite key yields every seed's
    valid-prefix length at once; seeds with an empty prefix come back fully
    masked.
    """
    qranks = jnp.searchsorted(adj["tvals"], query_t, side="left")
    qranks = qranks.astype(jnp.int32)
    starts = adj["indptr"][seeds]
    ends = jnp.searchsorted(adj["adj_key"], seeds * adj["base"] + qranks,
                            side="left").astype(jnp.int32)
    n_valid = ends - starts
    has = n_valid > 0
    B = seeds.shape[0]
    draw = jax.random.randint(rng_key, (B, k), 0,
                              jnp.maximum(n_valid, 1)[:, None], jnp.int32)
    idx = jnp.minimum(starts[:, None] + draw, adj["adj_nbr"].shape[0] - 1)
    ids = jnp.where(has[:, None], adj["adj_nbr"][idx], -1)
    times = jnp.where(has[:, None], adj["adj_t"][idx], 0)
    eids = jnp.where(has[:, None], adj["adj_e"][idx], -1)
    mask = jnp.broadcast_to(has[:, None], (B, k))
    return ids, times, eids, mask


class DeviceUniformSampler:
    """JAX device-resident uniform temporal neighbor sampler.

    Drop-in twin of ``UniformSampler``: ``build`` once per storage slice,
    then ``sample(seeds, query_t)`` draws K past neighbors per seed
    uniformly with replacement, entirely on ``device`` (default: first JAX
    device). Sampling uses a counter-derived PRNG key per call, so runs are
    reproducible and ``reset_state`` rewinds an epoch exactly.
    """

    def __init__(self, num_nodes: int, k: int, seed: int = 0, device=None,
                 checkpoint_adjacency: bool = True, mesh=None,
                 mesh_axis: str = "data", partition: str = "rows"):
        if k <= 0:
            raise ValueError("k must be positive")
        if partition not in ("rows", "degree"):
            raise ValueError(
                f"partition must be 'rows' or 'degree', got {partition!r}")
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self._seed = int(seed)
        self._counter = 0
        self._adj = None
        self.checkpoint_adjacency = bool(checkpoint_adjacency)
        self._mesh = mesh
        self._mesh_axis = mesh_axis
        self.partition = partition
        if mesh is not None:
            from repro.distributed.sharding import (
                node_rows_per_shard,
                replicated_sharding,
                row_sharding,
            )

            if device is not None:
                raise ValueError(
                    "pass either device= or mesh=, not both — a sharded "
                    "sampler's state is placed by the mesh's row sharding "
                    "(docs/sharding.md)"
                )
            if mesh_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no axis {mesh_axis!r}; axes are "
                    f"{mesh.axis_names}"
                )
            self._shards = int(mesh.shape[mesh_axis])
            self._per = node_rows_per_shard(self.num_nodes, self._shards)
            self._row_sharding = row_sharding(mesh, mesh_axis)
            self._replicated = replicated_sharding(mesh)
            self._device = None
        else:
            self._device = device or jax.devices()[0]

    # ------------------------------------------------------------------
    _as_i32 = staticmethod(as_int32)

    def build(self, src, dst, t, eids: Optional[np.ndarray] = None) -> None:
        """Build the device CSR-by-time adjacency for an edge storage slice.

        Each undirected event contributes both (src -> dst) and
        (dst -> src) entries. ``eids`` defaults to the event index, matching
        the ``EdgeFeatureLookupHook`` convention. Sharded samplers build on
        the host and place per-shard slices directly (``_host_csr`` +
        ``_shard_adjacency``), so the global adjacency never materializes
        on a single device — it may not fit one HBM by design.
        """
        if eids is None:
            eids = np.arange(len(np.asarray(src)), dtype=np.int64)
        if self._mesh is not None:
            src = self._host_i64(src, "src")
            dst = self._host_i64(dst, "dst")
            t2 = np.concatenate([self._host_i64(t, "t")] * 2)
            es = np.concatenate([self._host_i64(eids, "eids")] * 2)
            self._shard_adjacency(self._host_csr(
                np.concatenate([src, dst]), np.concatenate([dst, src]),
                t2, es))
            return
        nodes = jnp.concatenate([self._as_i32(src, "src"),
                                 self._as_i32(dst, "dst")])
        nbrs = jnp.concatenate([self._as_i32(dst, "dst"),
                                self._as_i32(src, "src")])
        times = jnp.concatenate([self._as_i32(t, "t")] * 2)
        es = jnp.concatenate([self._as_i32(eids, "eids")] * 2)
        adj = _build(nodes, nbrs, times, eids=es, num_nodes=self.num_nodes)
        # One host sync at build time (once per split) to verify the fused
        # int32 key cannot have overflowed: num_nodes * base must fit.
        base = int(adj["base"])
        if self.num_nodes * base >= 2**31:
            raise ValueError(
                f"composite key range num_nodes*({base}) exceeds int32; use "
                f"the host UniformSampler for this graph"
            )
        self._adj = jax.device_put(adj, self._device)

    def build_from_store(self, store, chunk_size: int = 1 << 20,
                         scratch_dir: Optional[str] = None) -> None:
        """Build the CSR from an ``EventStore`` via the streaming two-pass
        build (``repro.storage.streaming_csr``): degree count, then
        chunked fill — O(chunk) host-resident beyond the adjacency itself,
        which ``scratch_dir`` parks in disk-backed memmaps. Sharded
        samplers hand the streamed CSR straight to ``_shard_adjacency``
        (the same ``partition="rows"``/``"degree"`` boundary cut as
        ``build``), so each shard's padded slice goes host-scratch ->
        device with no full-size host copy; single-device samplers place
        the already-sorted arrays directly, skipping the device re-sort.
        Layout matches ``build`` bit-identically whenever no two distinct
        events share a ``(node, timestamp)`` pair (``repro/storage/csr.py``).
        """
        from repro.storage.csr import streaming_csr

        t_hi = store.time_span[1]
        if store.num_edge_events >= 2**30 or t_hi >= 2**31:
            raise ValueError(
                "stream exceeds the device sampler's int32 range "
                "(indptr/timestamps); use the host UniformSampler")
        csr = streaming_csr(store, num_nodes=self.num_nodes,
                            chunk_size=chunk_size, scratch_dir=scratch_dir)
        base = int(csr["base"])
        if self.num_nodes * base >= 2**31:
            raise ValueError(
                f"composite key range num_nodes*({base}) exceeds int32; use "
                f"the host UniformSampler for this graph"
            )
        if self._mesh is not None:
            self._shard_adjacency(csr)
            return
        adj = {
            "adj_nbr": self._as_i32(csr["adj_nbr"], "adj_nbr"),
            "adj_t": self._as_i32(csr["adj_t"], "adj_t"),
            "adj_e": self._as_i32(csr["adj_e"], "adj_e"),
            "adj_key": self._as_i32(csr["adj_key"], "adj_key"),
            "indptr": self._as_i32(csr["indptr"], "indptr"),
            "tvals": self._as_i32(csr["tvals"], "tvals"),
            "base": jnp.int32(base),
        }
        self._adj = jax.device_put(adj, self._device)

    @staticmethod
    def _host_i64(a, name: str) -> np.ndarray:
        """Host int64 view of an input array with the same int32-range
        guard as ``as_int32`` (the sharded arrays are narrowed to int32 at
        placement time, so out-of-range values must fail loudly here)."""
        a = np.asarray(jax.device_get(a)).astype(np.int64)
        if a.size and (a.max() >= 2**31 or a.min() < -(2**31)):
            raise ValueError(
                f"{name} exceeds int32 range; rescale (e.g. coarser time "
                f"granularity / epoch-relative timestamps) before "
                f"device sampling"
            )
        return a

    def _host_csr(self, nodes, nbrs, times, eids) -> dict:
        """Canonical node-major/time-ascending CSR built host-side with
        numpy — bit-identical layout to the jitted ``_build`` (both are
        stable sorts on the same (node, time-rank) composite key; see
        ``tests/test_sampler.py::test_device_uniform_adjacency_matches_host_csr``)
        — used by the sharded path so no device ever holds the full
        adjacency."""
        order = np.lexsort((times, nodes))
        nodes, nbrs = nodes[order], nbrs[order]
        times, eids = times[order], eids[order]
        counts = np.bincount(nodes, minlength=self.num_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        tvals = np.unique(times)
        base = len(tvals) + 1
        if self.num_nodes * base >= 2**31:
            raise ValueError(
                f"composite key range num_nodes*({base}) exceeds int32; use "
                f"the host UniformSampler for this graph"
            )
        key = nodes * base + np.searchsorted(tvals, times)
        return {"adj_nbr": nbrs, "adj_t": times, "adj_e": eids,
                "adj_key": key, "indptr": indptr, "tvals": tvals,
                "base": base}

    def _shard_bounds(self, indptr: np.ndarray) -> np.ndarray:
        """Per-shard node boundaries ``bounds`` (s+1,): shard ``i`` owns
        nodes ``[bounds[i], bounds[i+1])``.

        ``partition="rows"`` (default) keeps the equal-row-count split of
        ``node_rows_per_shard`` — shard ``i`` owns ``[i*per, (i+1)*per)``.
        ``partition="degree"`` cuts at the cumulative-degree quantiles
        instead (``searchsorted`` on the global indptr), so each shard
        holds roughly ``E/s`` adjacency entries — on skewed graphs this
        shrinks the max per-shard edge padding ``L`` (and with it every
        shard's CSR allocation) relative to the equal-rows split, at the
        cost of variable per-shard node counts (local indptr is padded to
        the max). Both splits draw identically: the prefix-length psum and
        the replicated draws do not depend on where the cuts fall.
        """
        s, n = self._shards, self.num_nodes
        if self.partition == "degree":
            total = int(indptr[n])
            targets = (np.arange(1, s, dtype=np.int64) * total) // s
            cuts = np.searchsorted(indptr[: n + 1], targets)
            bounds = np.concatenate([[0], cuts, [n]]).astype(np.int64)
            return np.maximum.accumulate(bounds)
        per = self._per
        return np.minimum(np.arange(s + 1, dtype=np.int64) * per, n)

    def _shard_adjacency(self, host: dict) -> None:
        """Split the host CSR on node boundaries and place it row-sharded.

        Shard ``i`` owns nodes ``[bounds[i], bounds[i+1])`` (see
        ``_shard_bounds`` for the equal-rows vs degree-balanced cut); its
        adjacency slice (a contiguous, still globally-sorted run of the
        node-major arrays) is padded to the max per-shard edge count ``L``
        — keys with int32 max so a local ``searchsorted`` never lands in
        padding, values with 0 (never read: gathers are masked by
        ownership and prefix length). Local ``indptr`` is rebased per
        shard and padded to the max per-shard node count (clamping at the
        shard's upper bound, so padding entries read as zero-degree).
        Each shard's padded slice is materialized directly on its device
        via ``jax.make_array_from_callback`` — no device (and no extra
        host copy) ever holds the padded global layout.
        """
        s, n = self._shards, self.num_nodes
        indptr = np.asarray(host["indptr"], np.int64)
        bounds = self._shard_bounds(indptr)
        node_lo, node_hi = bounds[:-1], bounds[1:]
        rows = max(int((node_hi - node_lo).max()), 1)
        off = indptr[node_lo]
        counts = indptr[node_hi] - off
        L = max(int(counts.max()), 1)

        def edge_cb(src, fill):
            def cb(index):
                i = (index[0].start or 0) // L
                out = np.full((L,), fill, np.int32)
                out[: counts[i]] = src[off[i]: off[i] + counts[i]]
                return out

            return jax.make_array_from_callback((s * L,),
                                                self._row_sharding, cb)

        def indptr_cb(index):
            i = (index[0].start or 0) // (rows + 1)
            nodes = np.minimum(node_lo[i] + np.arange(rows + 1), node_hi[i])
            return (indptr[nodes] - off[i]).astype(np.int32)

        self._adj = {
            "adj_nbr": edge_cb(np.asarray(host["adj_nbr"]), 0),
            "adj_t": edge_cb(np.asarray(host["adj_t"]), 0),
            "adj_e": edge_cb(np.asarray(host["adj_e"]), 0),
            "adj_key": edge_cb(np.asarray(host["adj_key"]), _I32_MAX),
            "indptr": jax.make_array_from_callback(
                (s * (rows + 1),), self._row_sharding, indptr_cb),
            "bounds": jax.device_put(jnp.asarray(bounds, jnp.int32),
                                     self._replicated),
            "tvals": jax.device_put(jnp.asarray(host["tvals"], jnp.int32),
                                    self._replicated),
            "base": jax.device_put(jnp.asarray(host["base"], jnp.int32),
                                   self._replicated),
        }
        self._host_indptr = indptr
        self._shard_counts = counts
        self._L = L
        self._make_sharded_sample()

    def _make_sharded_sample(self) -> None:
        """Build the per-instance jitted ``shard_map`` sample (see the
        module docstring for the two-psum combine)."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import SHARD_MAP_KW, shard_map

        mesh, axis = self._mesh, self._mesh_axis
        k, L = self.k, self._L
        adj_specs = {"adj_nbr": P(axis), "adj_t": P(axis), "adj_e": P(axis),
                     "adj_key": P(axis), "indptr": P(axis), "bounds": P(),
                     "tvals": P(), "base": P()}
        rep = P()

        def sample_body(adj, seeds, query_t, rng_key):
            i = jax.lax.axis_index(axis)
            lo, hi = adj["bounds"][i], adj["bounds"][i + 1]
            owned = (seeds >= lo) & (seeds < hi)
            qranks = jnp.searchsorted(adj["tvals"], query_t,
                                      side="left").astype(jnp.int32)
            starts = adj["indptr"][jnp.where(owned, seeds - lo, 0)]
            ends = jnp.searchsorted(
                adj["adj_key"], seeds * adj["base"] + qranks,
                side="left").astype(jnp.int32)
            # psum 1: every seed's valid-prefix length (owner's count).
            n_valid = jax.lax.psum(jnp.where(owned, ends - starts, 0), axis)
            # Replicated draws: same key/shape/bounds as the single-device
            # path, so the drawn offsets are bit-identical.
            draw = jax.random.randint(rng_key, (seeds.shape[0], k), 0,
                                      jnp.maximum(n_valid, 1)[:, None],
                                      jnp.int32)
            idx = jnp.minimum(starts[:, None] + draw, L - 1)
            rows = jnp.stack([adj["adj_nbr"][idx], adj["adj_t"][idx],
                              adj["adj_e"][idx]], axis=-1)
            # psum 2: the owner's gathered (id, time, eid) rows.
            rows = jax.lax.psum(
                jnp.where(owned[:, None, None], rows, 0), axis)
            return rows, n_valid

        smp = shard_map(sample_body, mesh=mesh,
                        in_specs=(adj_specs, rep, rep, rep),
                        out_specs=(rep, rep), **SHARD_MAP_KW)

        def sample(adj, seeds, query_t, rng_key):
            rows, n_valid = smp(adj, seeds, query_t, rng_key)
            has = n_valid > 0
            ids = jnp.where(has[:, None], rows[..., 0], -1)
            times = jnp.where(has[:, None], rows[..., 1], 0)
            eids = jnp.where(has[:, None], rows[..., 2], -1)
            mask = jnp.broadcast_to(has[:, None], (seeds.shape[0], k))
            return ids, times, eids, mask

        self._sharded_sample = jax.jit(sample)

    @property
    def _built(self) -> bool:
        return self._adj is not None

    def reset_state(self) -> None:
        """Rewind the draw counter (start of an epoch); keeps the built
        adjacency — it is a pure function of the storage slice."""
        self._counter = 0

    def sample(self, seeds, query_t) -> NeighborBlock:
        """Draw K uniform past neighbors per seed, strictly before
        ``query_t``. Returns a fixed-shape device ``NeighborBlock``."""
        if not self._built:
            raise RuntimeError("DeviceUniformSampler.build() must be called first")
        seeds = jnp.asarray(seeds, jnp.int32)
        query_t = self._as_i32(query_t, "query_t")
        rng_key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                     self._counter)
        self._counter += 1
        if self._mesh is not None:
            seeds, query_t, rng_key = jax.device_put(
                (seeds, query_t, rng_key), self._replicated)
            ids, times, eids, mask = self._sharded_sample(
                self._adj, seeds, query_t, rng_key)
        else:
            ids, times, eids, mask = _sample(self._adj, seeds, query_t,
                                             rng_key, k=self.k)
        return NeighborBlock(ids, times, eids, mask)

    # -- checkpoint contract (shared with UniformSampler) ----------------
    def state_dict(self) -> dict:
        """Canonical host-numpy state: the CSR arrays plus the draw counter.
        Loads into either uniform sampler, at any mesh size (sharded
        samplers reassemble the canonical node-major CSR first; resharding
        happens on load). Self-contained restore at an O(E) checkpoint cost
        — see ``UniformSampler.state_dict``. With
        ``checkpoint_adjacency=False``, counter-only: the restoring side
        rebuilds the CSR from storage via ``build(...)``."""
        if not self._built or not self.checkpoint_adjacency:
            return {"counter": np.int64(self._counter)}
        if self._mesh is None:
            host = jax.device_get(self._adj)
            nbr, t, e = host["adj_nbr"], host["adj_t"], host["adj_e"]
            indptr = host["indptr"]
        else:
            # Strip each shard's padding tail and re-concatenate the
            # node-major runs; the global indptr was kept at shard time.
            host = jax.device_get(
                {k: self._adj[k] for k in ("adj_nbr", "adj_t", "adj_e")})
            s, L, counts = self._shards, self._L, self._shard_counts
            nbr, t, e = (
                np.concatenate(
                    [host[k].reshape(s, L)[i, : counts[i]] for i in range(s)])
                for k in ("adj_nbr", "adj_t", "adj_e"))
            indptr = self._host_indptr
        return {
            "adj_nbr": nbr.astype(np.int64),
            "adj_t": t.astype(np.int64),
            "adj_e": e.astype(np.int64),
            "indptr": indptr.astype(np.int64),
            "counter": np.int64(self._counter),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore from either sampler's ``state_dict`` at any mesh size;
        the derived composite-key/time-rank arrays are rebuilt on device
        and re-split over this sampler's mesh (if any)."""
        self._counter = int(state["counter"])
        if "adj_nbr" not in state:
            return
        nodes, nbrs, times, eids = csr_from_state(state, self.num_nodes)
        if self._mesh is not None:
            self._shard_adjacency(self._host_csr(
                self._host_i64(nodes, "nodes"),
                self._host_i64(nbrs, "adj_nbr"),
                self._host_i64(times, "adj_t"),
                self._host_i64(eids, "adj_e")))
            return
        adj = _build(
            self._as_i32(nodes, "nodes"),
            self._as_i32(nbrs, "adj_nbr"),
            self._as_i32(times, "adj_t"),
            eids=self._as_i32(eids, "adj_e"),
            num_nodes=self.num_nodes,
        )
        self._adj = jax.device_put(adj, self._device)
