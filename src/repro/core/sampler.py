"""Temporal neighbor samplers (paper §4/§5: "fully vectorized recency
sampler, implemented with a circular buffer").

``RecencySampler`` keeps, per node, a fixed-size circular buffer of the K
most recent neighbor interactions. Insertion of a batch of B edges touches
O(B) buffer slots with pure vectorized scatter ops (no python loops over
events), and lookup of B seeds' neighbors is a single gather — the
cache-friendly access pattern the paper credits for its speedups.

``UniformSampler`` samples uniformly from *all* temporal neighbors before the
query time using the CSR-by-time layout built once per split.

Both produce fixed-shape ``(B, K)`` outputs (padded with ``-1``) so the
downstream JAX model steps compile once.

The scatter trick for duplicate seeds inside one batch: positions are
assigned per-node sequentially via a counting pass (np.add.at on a cursor
array), so multiple same-node events in one batch land in distinct slots in
chronological order — matching sequential insertion semantics exactly.

This module is the *host* implementation. Its device twin,
``repro.core.device_sampler.DeviceRecencySampler`` (selected by the
``device_sampling=True`` trainer/recipe flag), keeps bit-identical buffers
on the accelerator as a JAX pytree with jit-compiled update/sample; the two
share the ``state_dict`` checkpoint contract and are interchangeable. The
host version stays the parity oracle for tests and the CPU fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class NeighborBlock:
    """Fixed-shape neighborhood of a set of seed nodes at query times.

    ``nbr_ids[i, k]``   : k-th sampled neighbor of seed i (-1 = padding)
    ``nbr_times[i, k]`` : interaction timestamp (0 where padded)
    ``nbr_eids[i, k]``  : edge-event index into storage (-1 where padded)
    ``mask[i, k]``      : True where a real neighbor is present
    """

    nbr_ids: np.ndarray
    nbr_times: np.ndarray
    nbr_eids: np.ndarray
    mask: np.ndarray


class RecencySampler:
    """Vectorized most-recent-K temporal neighbor sampler (circular buffer).

    State: three ``(num_nodes, K)`` arrays (neighbor id, time, edge id) plus a
    ``(num_nodes,)`` write cursor. The buffer is undirected by default
    (each edge inserts dst into src's buffer and vice versa).
    """

    def __init__(self, num_nodes: int, k: int, directed: bool = False):
        if k <= 0:
            raise ValueError("k must be positive")
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self.directed = directed
        self.reset_state()

    def reset_state(self) -> None:
        """Clear buffers: ids/eids -1, times 0, cursor/count 0."""
        n, k = self.num_nodes, self.k
        self._ids = np.full((n, k), -1, dtype=np.int64)
        self._times = np.zeros((n, k), dtype=np.int64)
        self._eids = np.full((n, k), -1, dtype=np.int64)
        self._cursor = np.zeros(n, dtype=np.int64)
        self._count = np.zeros(n, dtype=np.int64)

    # ------------------------------------------------------------------
    def update(self, src: np.ndarray, dst: np.ndarray, t: np.ndarray,
               eids: Optional[np.ndarray] = None) -> None:
        """Insert a time-sorted batch of edges. Fully vectorized.

        For node u appearing m times in the batch, its m insertions are
        placed at slots ``cursor[u] + 0..m-1 (mod K)`` in chronological
        order — identical to sequential insertion.
        """
        if eids is None:
            eids = np.full(len(src), -1, dtype=np.int64)
        if self.directed:
            nodes = np.asarray(src, dtype=np.int64)
            nbrs = np.asarray(dst, dtype=np.int64)
            times = np.asarray(t, dtype=np.int64)
            es = np.asarray(eids, dtype=np.int64)
        else:
            # Interleave src/dst copies (event i -> positions 2i, 2i+1) so the
            # flattened stream preserves exact event order; the stable
            # argsort-by-node below then reproduces sequential insertion
            # semantics even for equal timestamps.
            B = len(src)
            nodes = np.empty(2 * B, dtype=np.int64)
            nbrs = np.empty(2 * B, dtype=np.int64)
            times = np.empty(2 * B, dtype=np.int64)
            es = np.empty(2 * B, dtype=np.int64)
            nodes[0::2], nodes[1::2] = src, dst
            nbrs[0::2], nbrs[1::2] = dst, src
            times[0::2], times[1::2] = t, t
            es[0::2], es[1::2] = eids, eids

        # Per-node sequence number within this batch.
        # counts[u] occurrences; seq via sort-by-node trick.
        order = np.argsort(nodes, kind="stable")
        sn, sb, st, se = nodes[order], nbrs[order], times[order], es[order]
        if len(sn) == 0:
            return
        group_start = np.empty(len(sn), dtype=bool)
        group_start[0] = True
        group_start[1:] = sn[1:] != sn[:-1]
        gidx = np.cumsum(group_start) - 1
        first_pos = np.flatnonzero(group_start)
        seq = np.arange(len(sn)) - first_pos[gidx]

        slots = (self._cursor[sn] + seq) % self.k
        self._ids[sn, slots] = sb
        self._times[sn, slots] = st
        self._eids[sn, slots] = se

        # Advance cursors by per-node multiplicity.
        uniq = sn[group_start]
        counts = np.diff(np.concatenate([first_pos, [len(sn)]]))
        self._cursor[uniq] = (self._cursor[uniq] + counts) % self.k
        self._count[uniq] = np.minimum(self._count[uniq] + counts, self.k)

    # ------------------------------------------------------------------
    def sample(self, seeds: np.ndarray, query_t: Optional[np.ndarray] = None) -> NeighborBlock:
        """Gather the (up to) K most recent neighbors of each seed.

        Output is ordered most-recent-first. ``query_t`` is accepted for API
        parity with ``UniformSampler``; recency state is only ever updated
        with past events, so no additional filtering is required, but when
        given it masks any neighbor with time > query_t (defensive).
        """
        seeds = np.asarray(seeds, dtype=np.int64)
        # Roll each row so that most-recent is first: the most recent write is
        # at cursor-1. Build gather indices (B, K).
        cur = self._cursor[seeds]  # (B,)
        offs = np.arange(1, self.k + 1)[None, :]  # 1..K
        slots = (cur[:, None] - offs) % self.k  # most recent first
        rows = seeds[:, None]
        ids = self._ids[rows, slots]
        times = self._times[rows, slots]
        eids = self._eids[rows, slots]
        mask = np.arange(self.k)[None, :] < self._count[seeds][:, None]
        if query_t is not None:
            mask = mask & (times <= np.asarray(query_t, dtype=np.int64)[:, None])
        ids = np.where(mask, ids, -1)
        times = np.where(mask, times, 0)
        eids = np.where(mask, eids, -1)
        return NeighborBlock(ids, times, eids, mask)

    # State as a pytree-compatible dict (checkpointable).
    def state_dict(self) -> dict:
        """Canonical ``{ids, times, eids, cursor, count}`` numpy state —
        loads into either recency sampler (host or device)."""
        return {
            "ids": self._ids, "times": self._times, "eids": self._eids,
            "cursor": self._cursor, "count": self._count,
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore buffers saved by either recency sampler."""
        self._ids = np.array(state["ids"], dtype=np.int64)
        self._times = np.array(state["times"], dtype=np.int64)
        self._eids = np.array(state["eids"], dtype=np.int64)
        self._cursor = np.array(state["cursor"], dtype=np.int64)
        self._count = np.array(state["count"], dtype=np.int64)


class SequentialRecencySampler(RecencySampler):
    """Python-loop reference implementation (oracle for property tests and
    the 'DyGLib-style' baseline in benchmarks)."""

    def update(self, src, dst, t, eids=None) -> None:
        if eids is None:
            eids = np.full(len(src), -1, dtype=np.int64)

        def _insert(u: int, v: int, tt: int, e: int) -> None:
            c = int(self._cursor[u])
            self._ids[u, c] = v
            self._times[u, c] = tt
            self._eids[u, c] = e
            self._cursor[u] = (c + 1) % self.k
            self._count[u] = min(self._count[u] + 1, self.k)

        for i in range(len(src)):
            _insert(int(src[i]), int(dst[i]), int(t[i]), int(eids[i]))
            if not self.directed:
                _insert(int(dst[i]), int(src[i]), int(t[i]), int(eids[i]))


def csr_from_state(state: dict, num_nodes: int):
    """Rebuild ``(nodes, nbrs, times, eids)`` int64 arrays from the shared
    uniform-sampler checkpoint contract (``adj_nbr/adj_t/adj_e/indptr``).
    The node column is implicit in ``indptr`` (node-major layout). Used by
    both ``UniformSampler`` and ``DeviceUniformSampler`` so the contract
    cannot silently diverge between the twins."""
    indptr = np.asarray(state["indptr"], dtype=np.int64)
    nodes = np.repeat(np.arange(num_nodes, dtype=np.int64), np.diff(indptr))
    return (nodes,
            np.asarray(state["adj_nbr"], dtype=np.int64),
            np.asarray(state["adj_t"], dtype=np.int64),
            np.asarray(state["adj_e"], dtype=np.int64))


class UniformSampler:
    """Uniform temporal neighbor sampling over *all* past neighbors.

    Built over a static CSR-by-time adjacency of an edge storage slice
    (strict ``t < query_t`` filtering at sample time keeps it leak-free even
    when built over the full stream); per query, finds the per-node prefix
    of neighbors with t < query_t by one global composite-key binary search
    and samples K uniformly (with replacement when fewer).

    Draws use a per-call counter-derived RNG (``default_rng((seed, n))``),
    so epochs replay exactly after ``reset_state``. This module is the
    *host* implementation; its device twin
    ``repro.core.device_uniform.DeviceUniformSampler`` shares the
    ``state_dict`` checkpoint contract (``adj_nbr/adj_t/adj_e/indptr/
    counter``), making the two interchangeable inside ``RECIPE_TGB_LINK``.
    """

    def __init__(self, num_nodes: int, k: int, seed: int = 0,
                 checkpoint_adjacency: bool = True):
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self._seed = seed
        self._counter = 0
        self._built = False
        self.checkpoint_adjacency = bool(checkpoint_adjacency)

    def build(self, src: np.ndarray, dst: np.ndarray, t: np.ndarray,
              eids: Optional[np.ndarray] = None) -> None:
        """Build the CSR-by-time adjacency (both directions per event)."""
        if eids is None:
            eids = np.arange(len(src), dtype=np.int64)
        nodes = np.concatenate([src, dst]).astype(np.int64)
        nbrs = np.concatenate([dst, src]).astype(np.int64)
        times = np.concatenate([t, t]).astype(np.int64)
        es = np.concatenate([eids, eids]).astype(np.int64)
        order = np.lexsort((times, nodes))  # by node, then time
        self._set_adjacency(nodes[order], nbrs[order], times[order], es[order])

    def build_from_store(self, store, chunk_size: int = 1 << 20,
                         scratch_dir: Optional[str] = None) -> None:
        """Build the adjacency from an ``EventStore`` without materializing
        the doubled edge list: the two-pass ``repro.storage.streaming_csr``
        (degree count, then chunked fill at per-node cursors) walks the
        stream in O(chunk)-resident windows — ``scratch_dir`` additionally
        parks the O(E) adjacency arrays on disk. Same layout as ``build``
        (bit-identical whenever no two distinct events share a
        ``(node, timestamp)`` pair — see ``repro/storage/csr.py``)."""
        from repro.storage.csr import streaming_csr

        csr = streaming_csr(store, num_nodes=self.num_nodes,
                            chunk_size=chunk_size, scratch_dir=scratch_dir,
                            with_keys=False)
        self._set_adjacency(*csr_from_state(csr, self.num_nodes))

    def _set_adjacency(self, nodes, nbrs, times, es) -> None:
        """Install a node-major/time-ascending adjacency and derive the
        search structures (unique-time table + fused key)."""
        self._adj_nbr = nbrs
        self._adj_t = times
        self._adj_e = es
        counts = np.bincount(nodes, minlength=self.num_nodes)
        self._indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        # Composite (node, time-rank) key, globally sorted because the
        # adjacency is node-major with times ascending within each node.
        # Ranking times through the unique-value table keeps the key range at
        # num_nodes * (#distinct times + 1), immune to raw-timestamp overflow;
        # one global searchsorted on this key replaces the per-seed binary
        # search loop in ``sample``.
        self._tvals = np.unique(self._adj_t)
        self._key_base = len(self._tvals) + 1
        tranks = np.searchsorted(self._tvals, self._adj_t)
        self._adj_key = nodes * self._key_base + tranks
        self._built = True

    def reset_state(self) -> None:
        """Rewind the draw counter (start of an epoch); the adjacency is a
        pure function of the storage slice and is kept."""
        self._counter = 0

    def sample(self, seeds: np.ndarray, query_t: np.ndarray) -> NeighborBlock:
        """Draw K uniform neighbors per seed, strictly before ``query_t``.

        Returns a fixed-shape ``NeighborBlock``; seeds with no past
        neighbors come back fully masked.
        """
        if not self._built:
            raise RuntimeError("UniformSampler.build() must be called first")
        seeds = np.asarray(seeds, dtype=np.int64)
        query_t = np.asarray(query_t, dtype=np.int64)
        B, K = len(seeds), self.k
        starts = self._indptr[seeds]
        # Per-seed count of neighbors strictly before query_t via one global
        # searchsorted on the (node, time-rank) composite key: entries with
        # key < seed * base + rank(query_t) are exactly "nodes before seed"
        # plus "seed's neighbors with t < query_t" (rank() is monotone).
        # Batch-level dedup first: duplicate (seed, query_t) pairs — the
        # whole hop-2 frontier of a one-vs-many eval batch, where every
        # negative shares the positives' sampled neighbors — collapse to
        # one key each, so the binary search over the O(E) adjacency runs
        # on the unique set and gathers back. Bit-identical to the direct
        # search (searchsorted is deterministic per key); the K draws below
        # stay per-seed, so duplicated seeds keep independent draws.
        qranks = np.searchsorted(self._tvals, query_t, side="left")
        keys = seeds * self._key_base + qranks
        uniq_keys, inverse = np.unique(keys, return_inverse=True)
        valid_ends = np.searchsorted(
            self._adj_key, uniq_keys, side="left"
        )[inverse.reshape(keys.shape)]
        n_valid = valid_ends - starts
        has = n_valid > 0
        rng = np.random.default_rng((self._seed, self._counter))
        self._counter += 1
        draw = rng.integers(0, np.maximum(n_valid, 1)[:, None], size=(B, K))
        idx = np.minimum(starts[:, None] + draw, len(self._adj_nbr) - 1)
        ids = np.where(has[:, None], self._adj_nbr[idx], -1)
        times = np.where(has[:, None], self._adj_t[idx], 0)
        eids = np.where(has[:, None], self._adj_e[idx], -1)
        mask = np.broadcast_to(has[:, None], (B, K)).copy()
        return NeighborBlock(ids, times, eids, mask)

    # -- checkpoint contract (shared with DeviceUniformSampler) ----------
    def state_dict(self) -> dict:
        """CSR arrays + draw counter; loads into either uniform sampler.

        Including the adjacency makes restore self-contained (no rebuild
        required) at an O(E) checkpoint cost. With
        ``checkpoint_adjacency=False`` only the draw counter is saved — the
        adjacency is a pure function of the storage slice, so the restoring
        side rebuilds it with ``build(...)`` from storage (what the
        trainers already do at construction), shrinking checkpoints from
        O(E) to O(1) for huge streams.
        """
        if not self._built or not self.checkpoint_adjacency:
            return {"counter": np.int64(self._counter)}
        return {
            "adj_nbr": self._adj_nbr, "adj_t": self._adj_t,
            "adj_e": self._adj_e, "indptr": self._indptr,
            "counter": np.int64(self._counter),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore from either uniform sampler's ``state_dict``. Counter-only
        states keep (or await) an adjacency built from storage."""
        self._counter = int(state["counter"])
        if "adj_nbr" not in state:
            return
        self._set_adjacency(*csr_from_state(state, self.num_nodes))
