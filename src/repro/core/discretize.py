"""Graph discretization ``psi_r`` (paper Def. 3.5), fully vectorized.

Maps a temporal graph at native granularity ``tau`` to a coarser granularity
``tau_hat``, grouping events into equivalence classes ``(floor(t/k), src,
dst)`` and applying a reduction ``r`` to each class's features.

Three interchangeable implementations:
  * ``discretize``        — vectorized numpy (lexsort + reduceat); the default
                            host path and the one benchmarked against UTG.
  * ``discretize_jax``    — jnp segment ops over the **jittable** padded core
                            ``discretize_edges_padded`` (static reduce, fixed
                            output capacity + valid-count), so granularity
                            conversion runs compiled on device. Same
                            semantics as the numpy path.
  * ``discretize_naive``  — UTG-style python-dict reference baseline, used as
                            the comparison point for Table 5 and as the oracle
                            in property tests.

Reductions: first | last | sum | mean | max | count.
``count`` appends (or creates) a 1-dim feature holding the multiplicity.

The jitted core is also what ``core.loader.snapshot_tensor`` uses to
tensorize a stream into the device-resident DTDG ``SnapshotTensor`` view —
see ``docs/dtdg.md``; ``docs/architecture.md`` (the CTDG/DTDG split) covers
where ``psi_r`` sits in the pipeline.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.granularity import TimeDelta
from repro.core.graph import DGData

_REDUCTIONS = ("first", "last", "sum", "mean", "max", "count")

_I32_SENTINEL = 2**31 - 1


def _coarse_ticks(data: DGData, new_gran: TimeDelta) -> int:
    native = data.granularity
    if native.is_event_ordered or new_gran.is_event_ordered:
        raise TypeError(
            "discretization requires real-time granularities; the "
            "event-ordered granularity is excluded from time ops (paper §3)"
        )
    return new_gran.ticks_per(native)


def _group_boundaries(
    src: np.ndarray, dst: np.ndarray, ct: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Stable group-by (ct, src, dst) on time-sorted input.

    Returns (order, starts): ``order`` is a stable lexsort permutation
    grouping equal keys contiguously while preserving time order within a
    group; ``starts`` indexes group heads in the permuted arrays.
    """
    # np.lexsort is stable; last key is primary.
    order = np.lexsort((dst, src, ct))
    s, d, c = src[order], dst[order], ct[order]
    if len(s) == 0:
        return order, np.zeros(0, dtype=np.int64)
    new_group = np.empty(len(s), dtype=bool)
    new_group[0] = True
    new_group[1:] = (c[1:] != c[:-1]) | (s[1:] != s[:-1]) | (d[1:] != d[:-1])
    starts = np.flatnonzero(new_group).astype(np.int64)
    return order, starts


def _reduce_feats(
    feats: Optional[np.ndarray],
    order: np.ndarray,
    starts: np.ndarray,
    counts: np.ndarray,
    reduce: str,
) -> Optional[np.ndarray]:
    if reduce == "count":
        base = None if feats is None else _reduce_feats(feats, order, starts, counts, "sum")
        cnt = counts.astype(np.float32)[:, None]
        return cnt if base is None else np.concatenate([base, cnt], axis=1)
    if feats is None:
        return None
    f = feats[order]
    if reduce == "first":
        return f[starts]
    if reduce == "last":
        ends = np.concatenate([starts[1:], [len(order)]]) - 1
        return f[ends]
    if reduce == "sum":
        return np.add.reduceat(f, starts, axis=0)
    if reduce == "mean":
        return np.add.reduceat(f, starts, axis=0) / counts.astype(np.float32)[:, None]
    if reduce == "max":
        return np.maximum.reduceat(f, starts, axis=0)
    raise ValueError(f"unknown reduction {reduce!r}; expected one of {_REDUCTIONS}")


def discretize(
    data: DGData, new_gran: TimeDelta, reduce: str = "first", backend: str = "numpy"
) -> DGData:
    """Vectorized ``psi_r(G, tau) -> (G_hat, tau_hat)``."""
    if reduce not in _REDUCTIONS:
        raise ValueError(f"unknown reduction {reduce!r}; expected one of {_REDUCTIONS}")
    if backend == "jax":
        return discretize_jax(data, new_gran, reduce=reduce)
    k = _coarse_ticks(data, new_gran)
    ct = data.edge_t // k

    order, starts = _group_boundaries(data.src, data.dst, ct)
    counts = np.diff(np.concatenate([starts, [len(order)]]))

    new_feats = _reduce_feats(data.edge_feats, order, starts, counts, reduce)

    src, dst, t = data.src[order][starts], data.dst[order][starts], ct[order][starts]

    # Node events collapse the same way keyed by (ct, node); reduction 'last'
    # (the most recent feature wins within a bucket).
    node_ids = node_t = node_feats = None
    if data.node_ids is not None:
        nct = data.node_t // k
        norder = np.lexsort((data.node_ids, nct))
        ni, nc = data.node_ids[norder], nct[norder]
        if len(ni):
            new_g = np.empty(len(ni), dtype=bool)
            new_g[0] = True
            new_g[1:] = (nc[1:] != nc[:-1]) | (ni[1:] != ni[:-1])
            nstarts = np.flatnonzero(new_g).astype(np.int64)
            nends = np.concatenate([nstarts[1:], [len(ni)]]) - 1
            node_ids, node_t = ni[nstarts], nc[nstarts]
            if data.node_feats is not None:
                node_feats = data.node_feats[norder][nends]
        else:
            node_ids, node_t = ni, nc

    return DGData.from_arrays(
        src,
        dst,
        t,
        edge_feats=new_feats,
        node_ids=node_ids,
        node_t=node_t,
        node_feats=node_feats,
        static_node_feats=data.static_node_feats,
        granularity=new_gran,
        num_nodes=data.num_nodes,
    )


def jax_discretize_supported(data: DGData, k: int,
                             edges_only: bool = False) -> bool:
    """True iff the int32 rank-sorted device path can represent this graph.

    The jitted core group-by is a three-level stable argsort (no dense pair
    key), so node ids only need to fit int32 individually
    (``num_nodes < 2**31``, guaranteed by construction) and the remaining
    conditions are on time: coarse ticks must fit int32
    (``max(t) // k < 2**31``); anything larger falls back to the host numpy
    path (which is int64 throughout). Raw timestamps beyond int32 are fine
    as long as the coarse ticks fit: callers pre-divide on the host
    (``_host_ticks``) before staging, since ``jnp.asarray`` would otherwise
    silently wrap int64 inputs under the default x64-disabled config.

    ``edges_only=True`` skips the node-event collapse-key condition (the
    dense ``tick * n + node`` key, which does bound ``num_nodes``) for
    callers that only consume edge structure, e.g. ``snapshot_tensor`` —
    their graphs stay on the compiled path even when the node-event keys
    would overflow.
    """
    n = max(int(data.num_nodes), 1)
    tmax = int(data.edge_t.max()) if len(data.edge_t) else 0
    if not edges_only and data.node_t is not None and len(data.node_t):
        tmax = max(tmax, int(data.node_t.max()))
        # The node-event collapse keys (tick * n + node) densely.
        if (tmax // max(k, 1) + 1) * n >= 2**31:
            return False
    return tmax // max(k, 1) < _I32_SENTINEL


def _host_ticks(t: np.ndarray, k: int):
    """Timestamps staged for the int32 device core: raw when they fit int32
    (the core divides by ``k`` on device), else pre-divided to coarse ticks
    on the host (int64 division; the guard ensures ticks fit) with the
    device-side divisor collapsing to 1. Returns ``(t_staged, k_device)``."""
    if len(t) and int(t.max()) >= _I32_SENTINEL:
        return t // k, 1
    return t, k


@partial(jax.jit, static_argnames=("k", "reduce", "capacity", "feat_dim"))
def discretize_edges_padded(src, dst, t, feats, *, k: int, reduce: str,
                            capacity: int, feat_dim: int):
    """Jittable ``psi_r`` over edge events with a fixed output capacity.

    The group-by ``(floor(t/k), src, dst)`` is computed with a three-level
    stable argsort (no dense composite key at all, so int32 is enough for
    any graph passing ``jax_discretize_supported`` — node counts are only
    bounded by int32 ids), and every output is padded to the static
    ``capacity``:

      src/dst : (capacity,) int32, coarse-tick-major sorted; 0 where padded
      ct      : (capacity,) int32 coarse ticks; int32-max sentinel where
                padded (keeps the array globally sorted for searchsorted)
      feats   : (capacity, feat_dim') float32 reduced features (or None when
                the input has none and ``reduce != 'count'``)
      count   : () int32 — number of valid groups (callers must check
                ``count <= capacity``; overflow silently drops the tail)

    Inputs must be time-sorted (as ``DGData`` guarantees) so the
    ``first``/``last`` reductions pick the chronologically first/last event
    of each class. ``capacity``/``reduce`` are static: one XLA compilation
    per (E, capacity, reduce) signature, after which granularity conversion
    is a single device dispatch — the compiled half of the paper's 175x
    discretization speedup story (see ``docs/dtdg.md``).
    """
    import jax.numpy as jnp
    from jax import ops as jops

    e = src.shape[0]
    src = src.astype(jnp.int32)
    dst = dst.astype(jnp.int32)
    ct = (t.astype(jnp.int32) // k).astype(jnp.int32)

    # Stable lexsort by (ct, src, dst): least-significant key first.
    order = jnp.argsort(dst, stable=True)
    order = order[jnp.argsort(src[order], stable=True)]
    order = order[jnp.argsort(ct[order], stable=True)]
    cs, ss, ds = ct[order], src[order], dst[order]
    new = jnp.ones(e, dtype=bool)
    if e > 1:
        new = new.at[1:].set(
            (cs[1:] != cs[:-1]) | (ss[1:] != ss[:-1]) | (ds[1:] != ds[:-1])
        )
    seg = jnp.cumsum(new.astype(jnp.int32)) - 1  # group id per sorted event
    count = new.astype(jnp.int32).sum()

    # Scatter group heads into the padded outputs (scatter OOB drops).
    head = jnp.where(new, seg, capacity)
    out_src = jnp.zeros(capacity, jnp.int32).at[head].set(ss)
    out_dst = jnp.zeros(capacity, jnp.int32).at[head].set(ds)
    out_ct = jnp.full(capacity, _I32_SENTINEL, jnp.int32).at[head].set(cs)

    out_feats = None
    if feat_dim or reduce == "count":
        counts = jops.segment_sum(jnp.ones(e, jnp.float32), seg, capacity)
        f = None if not feat_dim else feats[order].astype(jnp.float32)
        if reduce in ("first", "last"):
            idx = jnp.arange(e, dtype=jnp.int32)
            pick = (
                jops.segment_min(idx, seg, capacity)
                if reduce == "first"
                else jops.segment_max(idx, seg, capacity)
            )
            pick = jnp.clip(pick, 0, max(e - 1, 0))
            out_feats = None if f is None else f[pick]
        elif reduce == "sum":
            out_feats = None if f is None else jops.segment_sum(f, seg, capacity)
        elif reduce == "mean":
            out_feats = (
                None if f is None
                else jops.segment_sum(f, seg, capacity)
                / jnp.maximum(counts, 1.0)[:, None]
            )
        elif reduce == "max":
            out_feats = None if f is None else jops.segment_max(f, seg, capacity)
        elif reduce == "count":
            base = None if f is None else jops.segment_sum(f, seg, capacity)
            cnt = counts[:, None]
            out_feats = cnt if base is None else jnp.concatenate([base, cnt], 1)
        if out_feats is not None:
            valid = jnp.arange(capacity) < count
            out_feats = jnp.where(valid[:, None], out_feats, 0.0)
    return out_src, out_dst, out_ct, out_feats, count


def discretize_jax(data: DGData, new_gran: TimeDelta, reduce: str = "first") -> DGData:
    """Device implementation of ``psi_r`` over the jitted padded core.

    Runs ``discretize_edges_padded`` at ``capacity=E`` (an upper bound on
    the number of classes) and slices to the valid count; node events
    collapse through eager segment ops as before. Falls back to the numpy
    path when the graph exceeds the int32 guard
    (``jax_discretize_supported``).
    """
    import jax.numpy as jnp
    from jax import ops as jops

    if reduce not in _REDUCTIONS:
        raise ValueError(f"unknown reduction {reduce!r}; expected one of {_REDUCTIONS}")
    k = _coarse_ticks(data, new_gran)
    if not jax_discretize_supported(data, k):
        return discretize(data, new_gran, reduce=reduce, backend="numpy")
    n = max(int(data.num_nodes), 1)
    e = data.num_edge_events
    if e == 0:
        return discretize(data, new_gran, reduce=reduce, backend="numpy")

    feat_dim = data.edge_feat_dim
    feats_in = (
        jnp.zeros((e, 0), jnp.float32)
        if feat_dim == 0
        else jnp.asarray(data.edge_feats, jnp.float32)
    )
    t_staged, k_dev = _host_ticks(data.edge_t, k)
    usrc, udst, ut, feats, count = discretize_edges_padded(
        jnp.asarray(data.src), jnp.asarray(data.dst), jnp.asarray(t_staged),
        feats_in, k=k_dev, reduce=reduce, capacity=e, feat_dim=feat_dim,
    )
    g = int(count)  # one host sync to slice the valid prefix
    usrc, udst, ut = usrc[:g], udst[:g], ut[:g]
    if feats is not None:
        feats = feats[:g]

    node_kwargs = {}
    if data.node_ids is not None:
        # Node events collapse through the same device segment ops as edges,
        # keyed by (coarse tick, node) with reduction 'last' (most recent
        # feature wins within a bucket; inputs are time-sorted so the max
        # within-segment index is the latest event).
        nids = jnp.asarray(data.node_ids)
        nt_staged, nk_dev = _host_ticks(data.node_t, k)
        nct = jnp.asarray(nt_staged) // nk_dev
        if len(data.node_ids):
            nkey = nct * n + nids
            nukey, nseg = jnp.unique(nkey, return_inverse=True)
            ng = len(nukey)
            node_kwargs = dict(
                node_ids=np.asarray(nukey % n),
                node_t=np.asarray(nukey // n),
            )
            if data.node_feats is not None:
                npick = jops.segment_max(jnp.arange(len(nseg)), nseg, ng)
                node_kwargs["node_feats"] = np.asarray(
                    jnp.asarray(data.node_feats)[npick]
                )
        else:
            node_kwargs = dict(
                node_ids=np.asarray(nids), node_t=np.asarray(nct)
            )

    return DGData.from_arrays(
        np.asarray(usrc),
        np.asarray(udst),
        np.asarray(ut),
        edge_feats=None if feats is None else np.asarray(feats),
        static_node_feats=data.static_node_feats,
        granularity=new_gran,
        num_nodes=data.num_nodes,
        **node_kwargs,
    )


def discretize_naive(data: DGData, new_gran: TimeDelta, reduce: str = "first") -> DGData:
    """UTG-style dict-based baseline (deliberately unvectorized).

    This mirrors the reference implementation the paper benchmarks against in
    Table 5: python loops over events, dict of (snapshot, src, dst) keys.
    """
    k = _coarse_ticks(data, new_gran)
    groups: dict = {}
    for i in range(data.num_edge_events):
        key = (int(data.edge_t[i]) // k, int(data.src[i]), int(data.dst[i]))
        groups.setdefault(key, []).append(i)

    keys = sorted(groups.keys())
    src = np.array([kk[1] for kk in keys], dtype=np.int64)
    dst = np.array([kk[2] for kk in keys], dtype=np.int64)
    t = np.array([kk[0] for kk in keys], dtype=np.int64)
    feats = None
    if data.edge_feats is not None or reduce == "count":
        rows = []
        for kk in keys:
            idx = groups[kk]
            if data.edge_feats is None:
                rows.append(np.array([len(idx)], dtype=np.float32))
                continue
            f = data.edge_feats[idx]
            if reduce == "first":
                r = f[0]
            elif reduce == "last":
                r = f[-1]
            elif reduce == "sum":
                r = f.sum(0)
            elif reduce == "mean":
                r = f.mean(0)
            elif reduce == "max":
                r = f.max(0)
            elif reduce == "count":
                r = np.concatenate([f.sum(0), [np.float32(len(idx))]])
            rows.append(r)
        feats = np.stack(rows).astype(np.float32)

    return DGData.from_arrays(
        src, dst, t, edge_feats=feats,
        static_node_feats=data.static_node_feats,
        granularity=new_gran, num_nodes=data.num_nodes,
    )
