"""Graph discretization ``psi_r`` (paper Def. 3.5), fully vectorized.

Maps a temporal graph at native granularity ``tau`` to a coarser granularity
``tau_hat``, grouping events into equivalence classes ``(floor(t/k), src,
dst)`` and applying a reduction ``r`` to each class's features.

Three implementations:
  * ``discretize``        — vectorized numpy (lexsort + reduceat); the default
                            host path and the one benchmarked against UTG.
  * ``discretize_jax``    — vectorized jnp segment ops (eager; device-resident
                            data). Same semantics.
  * ``discretize_naive``  — UTG-style python-dict reference baseline, used as
                            the comparison point for Table 5 and as the oracle
                            in property tests.

Reductions: first | last | sum | mean | max | count.
``count`` appends (or creates) a 1-dim feature holding the multiplicity.

See ``docs/architecture.md`` (the CTDG/DTDG split) for where ``psi_r`` sits
in the pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.granularity import TimeDelta
from repro.core.graph import DGData

_REDUCTIONS = ("first", "last", "sum", "mean", "max", "count")


def _coarse_ticks(data: DGData, new_gran: TimeDelta) -> int:
    native = data.granularity
    if native.is_event_ordered or new_gran.is_event_ordered:
        raise TypeError(
            "discretization requires real-time granularities; the "
            "event-ordered granularity is excluded from time ops (paper §3)"
        )
    return new_gran.ticks_per(native)


def _group_boundaries(
    src: np.ndarray, dst: np.ndarray, ct: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Stable group-by (ct, src, dst) on time-sorted input.

    Returns (order, starts): ``order`` is a stable lexsort permutation
    grouping equal keys contiguously while preserving time order within a
    group; ``starts`` indexes group heads in the permuted arrays.
    """
    # np.lexsort is stable; last key is primary.
    order = np.lexsort((dst, src, ct))
    s, d, c = src[order], dst[order], ct[order]
    if len(s) == 0:
        return order, np.zeros(0, dtype=np.int64)
    new_group = np.empty(len(s), dtype=bool)
    new_group[0] = True
    new_group[1:] = (c[1:] != c[:-1]) | (s[1:] != s[:-1]) | (d[1:] != d[:-1])
    starts = np.flatnonzero(new_group).astype(np.int64)
    return order, starts


def _reduce_feats(
    feats: Optional[np.ndarray],
    order: np.ndarray,
    starts: np.ndarray,
    counts: np.ndarray,
    reduce: str,
) -> Optional[np.ndarray]:
    if reduce == "count":
        base = None if feats is None else _reduce_feats(feats, order, starts, counts, "sum")
        cnt = counts.astype(np.float32)[:, None]
        return cnt if base is None else np.concatenate([base, cnt], axis=1)
    if feats is None:
        return None
    f = feats[order]
    if reduce == "first":
        return f[starts]
    if reduce == "last":
        ends = np.concatenate([starts[1:], [len(order)]]) - 1
        return f[ends]
    if reduce == "sum":
        return np.add.reduceat(f, starts, axis=0)
    if reduce == "mean":
        return np.add.reduceat(f, starts, axis=0) / counts.astype(np.float32)[:, None]
    if reduce == "max":
        return np.maximum.reduceat(f, starts, axis=0)
    raise ValueError(f"unknown reduction {reduce!r}; expected one of {_REDUCTIONS}")


def discretize(
    data: DGData, new_gran: TimeDelta, reduce: str = "first", backend: str = "numpy"
) -> DGData:
    """Vectorized ``psi_r(G, tau) -> (G_hat, tau_hat)``."""
    if reduce not in _REDUCTIONS:
        raise ValueError(f"unknown reduction {reduce!r}; expected one of {_REDUCTIONS}")
    if backend == "jax":
        return discretize_jax(data, new_gran, reduce=reduce)
    k = _coarse_ticks(data, new_gran)
    ct = data.edge_t // k

    order, starts = _group_boundaries(data.src, data.dst, ct)
    counts = np.diff(np.concatenate([starts, [len(order)]]))

    new_feats = _reduce_feats(data.edge_feats, order, starts, counts, reduce)

    src, dst, t = data.src[order][starts], data.dst[order][starts], ct[order][starts]

    # Node events collapse the same way keyed by (ct, node); reduction 'last'
    # (the most recent feature wins within a bucket).
    node_ids = node_t = node_feats = None
    if data.node_ids is not None:
        nct = data.node_t // k
        norder = np.lexsort((data.node_ids, nct))
        ni, nc = data.node_ids[norder], nct[norder]
        if len(ni):
            new_g = np.empty(len(ni), dtype=bool)
            new_g[0] = True
            new_g[1:] = (nc[1:] != nc[:-1]) | (ni[1:] != ni[:-1])
            nstarts = np.flatnonzero(new_g).astype(np.int64)
            nends = np.concatenate([nstarts[1:], [len(ni)]]) - 1
            node_ids, node_t = ni[nstarts], nc[nstarts]
            if data.node_feats is not None:
                node_feats = data.node_feats[norder][nends]
        else:
            node_ids, node_t = ni, nc

    return DGData.from_arrays(
        src,
        dst,
        t,
        edge_feats=new_feats,
        node_ids=node_ids,
        node_t=node_t,
        node_feats=node_feats,
        static_node_feats=data.static_node_feats,
        granularity=new_gran,
        num_nodes=data.num_nodes,
    )


def discretize_jax(data: DGData, new_gran: TimeDelta, reduce: str = "first") -> DGData:
    """jnp segment-op implementation (device-vectorized, eager)."""
    import jax.numpy as jnp
    from jax import ops as jops

    k = _coarse_ticks(data, new_gran)
    src = jnp.asarray(data.src)
    dst = jnp.asarray(data.dst)
    ct = jnp.asarray(data.edge_t) // k

    n = max(int(data.num_nodes), 1)
    # Dense composite key; guard overflow by falling back to numpy on huge ids.
    tmax = int(ct.max()) + 1 if len(data.edge_t) else 1
    if data.node_t is not None and len(data.node_t):
        tmax = max(tmax, int(data.node_t.max()) // k + 1)
    if tmax * n * n >= 2**62:
        return discretize(data, new_gran, reduce=reduce, backend="numpy")
    key = (ct * n + src) * n + dst
    ukey, seg = jnp.unique(key, return_inverse=True)
    g = len(ukey)
    counts = jops.segment_sum(jnp.ones_like(seg, dtype=jnp.float32), seg, g)

    usrc = (ukey // n) % n
    udst = ukey % n
    ut = ukey // (n * n)

    feats = None
    if data.edge_feats is not None or reduce == "count":
        f = None if data.edge_feats is None else jnp.asarray(data.edge_feats)
        if reduce in ("first", "last"):
            idx = jnp.arange(len(seg))
            pick = (
                jops.segment_min(idx, seg, g)
                if reduce == "first"
                else jops.segment_max(idx, seg, g)
            )
            feats = None if f is None else f[pick]
        elif reduce == "sum":
            feats = None if f is None else jops.segment_sum(f, seg, g)
        elif reduce == "mean":
            feats = None if f is None else jops.segment_sum(f, seg, g) / counts[:, None]
        elif reduce == "max":
            feats = None if f is None else jops.segment_max(f, seg, g)
        elif reduce == "count":
            base = None if f is None else jops.segment_sum(f, seg, g)
            feats = (
                counts[:, None]
                if base is None
                else jnp.concatenate([base, counts[:, None]], axis=1)
            )

    node_kwargs = {}
    if data.node_ids is not None:
        # Node events collapse through the same device segment ops as edges,
        # keyed by (coarse tick, node) with reduction 'last' (most recent
        # feature wins within a bucket; inputs are time-sorted so the max
        # within-segment index is the latest event).
        nids = jnp.asarray(data.node_ids)
        nct = jnp.asarray(data.node_t) // k
        if len(data.node_ids):
            nkey = nct * n + nids
            nukey, nseg = jnp.unique(nkey, return_inverse=True)
            ng = len(nukey)
            node_kwargs = dict(
                node_ids=np.asarray(nukey % n),
                node_t=np.asarray(nukey // n),
            )
            if data.node_feats is not None:
                npick = jops.segment_max(jnp.arange(len(nseg)), nseg, ng)
                node_kwargs["node_feats"] = np.asarray(
                    jnp.asarray(data.node_feats)[npick]
                )
        else:
            node_kwargs = dict(
                node_ids=np.asarray(nids), node_t=np.asarray(nct)
            )

    return DGData.from_arrays(
        np.asarray(usrc),
        np.asarray(udst),
        np.asarray(ut),
        edge_feats=None if feats is None else np.asarray(feats),
        static_node_feats=data.static_node_feats,
        granularity=new_gran,
        num_nodes=data.num_nodes,
        **node_kwargs,
    )


def discretize_naive(data: DGData, new_gran: TimeDelta, reduce: str = "first") -> DGData:
    """UTG-style dict-based baseline (deliberately unvectorized).

    This mirrors the reference implementation the paper benchmarks against in
    Table 5: python loops over events, dict of (snapshot, src, dst) keys.
    """
    k = _coarse_ticks(data, new_gran)
    groups: dict = {}
    for i in range(data.num_edge_events):
        key = (int(data.edge_t[i]) // k, int(data.src[i]), int(data.dst[i]))
        groups.setdefault(key, []).append(i)

    keys = sorted(groups.keys())
    src = np.array([kk[1] for kk in keys], dtype=np.int64)
    dst = np.array([kk[2] for kk in keys], dtype=np.int64)
    t = np.array([kk[0] for kk in keys], dtype=np.int64)
    feats = None
    if data.edge_feats is not None or reduce == "count":
        rows = []
        for kk in keys:
            idx = groups[kk]
            if data.edge_feats is None:
                rows.append(np.array([len(idx)], dtype=np.float32))
                continue
            f = data.edge_feats[idx]
            if reduce == "first":
                r = f[0]
            elif reduce == "last":
                r = f[-1]
            elif reduce == "sum":
                r = f.sum(0)
            elif reduce == "mean":
                r = f.mean(0)
            elif reduce == "max":
                r = f.max(0)
            elif reduce == "count":
                r = np.concatenate([f.sum(0), [np.float32(len(idx))]])
            rows.append(r)
        feats = np.stack(rows).astype(np.float32)

    return DGData.from_arrays(
        src, dst, t, edge_feats=feats,
        static_node_feats=data.static_node_feats,
        granularity=new_gran, num_nodes=data.num_nodes,
    )
