"""Immutable time-sorted COO storage and lightweight graph views (paper §4).

``DGData`` owns the event arrays (struct-of-arrays, time-sorted, with the
timestamp array doubling as a binary-search index). ``DGraph`` is a
lightweight *view*: a (storage, t_lo, t_hi, granularity) tuple that is O(1)
to create and concurrency-safe because the storage is immutable.

Root storage lives in host numpy; batches are materialized to device
tensors by the loader/hook pipeline (the ``device_transfer`` hook). The
DTDG path additionally has a *device-resident* view: ``SnapshotTensor``,
the discretized stream tensorized once into padded ``(T, capacity)``
src/dst/mask JAX arrays (built by ``core.loader.snapshot_tensor`` via the
jitted ``discretize_edges_padded``), which is what the scan-compiled
snapshot pipeline consumes — see ``docs/dtdg.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.granularity import TimeDelta


def _as_int64(x) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(x, dtype=np.int64))


def _as_f32(x) -> Optional[np.ndarray]:
    if x is None:
        return None
    return np.ascontiguousarray(np.asarray(x, dtype=np.float32))


def _int64_col(strings: np.ndarray) -> np.ndarray:
    """Parse a string column to int64 exactly; float-formatted cells
    ("3.0") fall back through float64 (truncating like the old
    ``genfromtxt`` path did)."""
    try:
        return strings.astype(np.int64)
    except ValueError:
        return strings.astype(np.float64).astype(np.int64)


def iter_csv_chunks(
    path: str,
    src_col: int = 0,
    dst_col: int = 1,
    t_col: int = 2,
    feat_cols: Optional[Sequence[int]] = None,
    delimiter: str = ",",
    skip_header: int = 1,
    chunk_rows: int = 1 << 16,
):
    """Stream a CSV of events as ``{"src", "dst", "t"[, "edge_feats"]}``
    numpy chunks of at most ``chunk_rows`` rows.

    Only one chunk is resident at a time: this is the parser behind both
    the chunked ``DGData.from_csv`` and the out-of-core
    ``repro.storage.MmapStore.from_csv`` converter. Integer id/time
    columns parse straight to int64 (no float64 round-trip), features to
    float32. Blank lines are skipped.
    """
    if chunk_rows <= 0:
        raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
    fcols = list(feat_cols) if feat_cols else None
    with open(path) as f:
        for _ in range(skip_header):
            f.readline()
        while True:
            lines = []
            for line in f:
                if line.strip():
                    lines.append(line)
                if len(lines) >= chunk_rows:
                    break
            if not lines:
                return
            cells = np.array([ln.strip().split(delimiter) for ln in lines])
            chunk = {
                "src": _int64_col(cells[:, src_col]),
                "dst": _int64_col(cells[:, dst_col]),
                "t": _int64_col(cells[:, t_col]),
            }
            if fcols:
                chunk["edge_feats"] = cells[:, fcols].astype(np.float32)
            yield chunk


@dataclasses.dataclass(frozen=True)
class DGData:
    """Immutable temporal-graph storage.

    Edge events:  ``(edge_t[i], src[i], dst[i], edge_feats[i])`` sorted by t.
    Node events:  ``(node_t[j], node_ids[j], node_feats[j])`` sorted by t.
    ``static_node_feats`` is the optional ``X in R^{n x d_static}``.
    """

    src: np.ndarray
    dst: np.ndarray
    edge_t: np.ndarray
    edge_feats: Optional[np.ndarray] = None
    node_ids: Optional[np.ndarray] = None
    node_t: Optional[np.ndarray] = None
    node_feats: Optional[np.ndarray] = None
    static_node_feats: Optional[np.ndarray] = None
    granularity: TimeDelta = dataclasses.field(default_factory=TimeDelta.event)
    num_nodes: int = 0
    # Global index of this storage's first edge event in its root storage
    # (0 for unsliced data; set by ``slice_events``). Lets loaders emit
    # *global* event ids for sliced splits, so edge-feature lookups keyed by
    # eid stay correct across train/val/test iteration.
    eid_offset: int = 0

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def from_arrays(
        cls,
        src,
        dst,
        edge_t,
        edge_feats=None,
        node_ids=None,
        node_t=None,
        node_feats=None,
        static_node_feats=None,
        granularity: TimeDelta | str = "s",
        num_nodes: Optional[int] = None,
    ) -> "DGData":
        src, dst, edge_t = _as_int64(src), _as_int64(dst), _as_int64(edge_t)
        if not (len(src) == len(dst) == len(edge_t)):
            raise ValueError("src/dst/edge_t length mismatch")
        edge_feats = _as_f32(edge_feats)
        if edge_feats is not None and len(edge_feats) != len(src):
            raise ValueError("edge_feats length mismatch")

        # Stable sort by timestamp preserves intra-timestamp event order.
        order = np.argsort(edge_t, kind="stable")
        src, dst, edge_t = src[order], dst[order], edge_t[order]
        if edge_feats is not None:
            edge_feats = edge_feats[order]

        if node_ids is not None:
            node_ids, node_t = _as_int64(node_ids), _as_int64(node_t)
            node_feats = _as_f32(node_feats)
            norder = np.argsort(node_t, kind="stable")
            node_ids, node_t = node_ids[norder], node_t[norder]
            if node_feats is not None:
                node_feats = node_feats[norder]

        if num_nodes is None:
            hi = 0
            if len(src):
                hi = max(hi, int(src.max()) + 1, int(dst.max()) + 1)
            if node_ids is not None and len(node_ids):
                hi = max(hi, int(node_ids.max()) + 1)
            num_nodes = hi

        static_node_feats = _as_f32(static_node_feats)
        return cls(
            src=src,
            dst=dst,
            edge_t=edge_t,
            edge_feats=edge_feats,
            node_ids=node_ids,
            node_t=node_t,
            node_feats=node_feats,
            static_node_feats=static_node_feats,
            granularity=TimeDelta.coerce(granularity),
            num_nodes=num_nodes,
        )

    @classmethod
    def from_csv(
        cls,
        path: str,
        src_col: int = 0,
        dst_col: int = 1,
        t_col: int = 2,
        feat_cols: Optional[Sequence[int]] = None,
        delimiter: str = ",",
        skip_header: int = 1,
        granularity: TimeDelta | str = "s",
        chunk_rows: int = 1 << 16,
    ) -> "DGData":
        """CSV IO adapter (paper §4: custom adapters via CSV).

        The parse streams in ``chunk_rows``-line chunks
        (``iter_csv_chunks``): id/time columns are parsed straight to
        int64 (event ids stay int64 end-to-end until device staging — no
        float round-trip that could silently lose precision on huge
        streams) and features to float32, so peak parse memory is one
        chunk plus the final columns instead of the whole file's float64
        matrix. For streams that should never be fully resident, convert
        to a store instead: ``repro.storage.MmapStore.from_csv``.
        """
        parts = {"src": [], "dst": [], "t": [], "edge_feats": []}
        for chunk in iter_csv_chunks(
            path, src_col=src_col, dst_col=dst_col, t_col=t_col,
            feat_cols=feat_cols, delimiter=delimiter,
            skip_header=skip_header, chunk_rows=chunk_rows,
        ):
            for k in ("src", "dst", "t"):
                parts[k].append(chunk[k])
            if "edge_feats" in chunk:
                parts["edge_feats"].append(chunk["edge_feats"])
        cat = lambda k, d: (
            np.concatenate(parts[k]) if parts[k] else np.empty((0,), d))
        feats = np.concatenate(parts["edge_feats"]) if parts["edge_feats"] else None
        return cls.from_arrays(
            cat("src", np.int64), cat("dst", np.int64), cat("t", np.int64),
            edge_feats=feats, granularity=granularity,
        )

    @classmethod
    def from_store(cls, store) -> "DGData":
        """Zero-copy ``DGData`` view over an ``EventStore`` backend.

        Columns are aliased, not copied: for ``InMemoryStore`` they are
        the same host arrays ``from_arrays`` would produce (bit-identical
        pipelines); for ``MmapStore`` they are read-only ``np.memmap``
        views, so slicing/splitting/loading downstream reads O(touched
        pages) from disk — the whole training stack runs off a store
        handle without ever materializing the stream (``docs/storage.md``).
        The store guarantees time-sorted columns, so no re-sort happens.
        """
        return cls(
            src=store.src,
            dst=store.dst,
            edge_t=store.edge_t,
            edge_feats=store.edge_feats,
            node_ids=store.node_ids,
            node_t=store.node_t,
            node_feats=store.node_feats,
            static_node_feats=store.static_node_feats,
            granularity=store.granularity,
            num_nodes=int(store.num_nodes),
        )

    def to_store(self):
        """This storage as an ``InMemoryStore`` (columns aliased, not
        copied) — the inverse of ``from_store`` for the default backend."""
        from repro.storage import InMemoryStore

        return InMemoryStore.from_data(self)

    # ------------------------------------------------------------------
    # Basic properties
    # ------------------------------------------------------------------
    @property
    def num_edge_events(self) -> int:
        return len(self.src)

    @property
    def num_node_events(self) -> int:
        return 0 if self.node_ids is None else len(self.node_ids)

    @property
    def edge_feat_dim(self) -> int:
        return 0 if self.edge_feats is None else self.edge_feats.shape[1]

    @property
    def node_feat_dim(self) -> int:
        return 0 if self.node_feats is None else self.node_feats.shape[1]

    @property
    def time_span(self) -> Tuple[int, int]:
        """[min_t, max_t] over all events (edge + node)."""
        ts = [self.edge_t] if len(self.edge_t) else []
        if self.node_t is not None and len(self.node_t):
            ts.append(self.node_t)
        if not ts:
            return (0, 0)
        return (int(min(t[0] for t in ts)), int(max(t[-1] for t in ts)))

    # ------------------------------------------------------------------
    # Splits
    # ------------------------------------------------------------------
    def split(
        self, val_ratio: float = 0.15, test_ratio: float = 0.15
    ) -> Tuple["DGData", "DGData", "DGData"]:
        """Chronological split by edge-event count (TGB convention).

        Boundary timestamps are respected: the split points are snapped so a
        single timestamp never straddles two splits.
        """
        n = self.num_edge_events
        i_val = int(n * (1.0 - val_ratio - test_ratio))
        i_test = int(n * (1.0 - test_ratio))
        # Snap split indices to timestamp boundaries.
        i_val = int(np.searchsorted(self.edge_t, self.edge_t[min(i_val, n - 1)]))
        i_test = int(np.searchsorted(self.edge_t, self.edge_t[min(i_test, n - 1)]))
        t_val = int(self.edge_t[i_val]) if i_val < n else self.time_span[1] + 1
        t_test = int(self.edge_t[i_test]) if i_test < n else self.time_span[1] + 1
        return (
            self.slice_events(0, i_val, t_hi=t_val),
            self.slice_events(i_val, i_test, t_hi=t_test),
            self.slice_events(i_test, n, t_hi=None),
        )

    def slice_events(self, lo: int, hi: int, t_hi: Optional[int] = None) -> "DGData":
        """Sub-storage of edge events [lo, hi); node events filtered by time.

        ``lo == hi`` (an empty window) is valid and yields an empty slice;
        ``lo > hi`` or rows outside ``[0, num_edge_events]`` raise
        ``ValueError`` — silently clamping used to produce empty or
        misaligned feature slices downstream.
        """
        n = self.num_edge_events
        if lo > hi:
            raise ValueError(f"slice_events lo {lo} > hi {hi}")
        if lo < 0 or hi > n:
            raise ValueError(
                f"slice_events window [{lo}, {hi}) out of range [0, {n})")
        t_lo_bound = int(self.edge_t[lo]) if lo < self.num_edge_events and lo < hi else 0
        nsel = slice(0, 0)
        if self.node_ids is not None:
            n_lo = int(np.searchsorted(self.node_t, t_lo_bound, side="left"))
            n_hi = (
                int(np.searchsorted(self.node_t, t_hi, side="left"))
                if t_hi is not None
                else len(self.node_t)
            )
            nsel = slice(n_lo, n_hi)
        return dataclasses.replace(
            self,
            src=self.src[lo:hi],
            dst=self.dst[lo:hi],
            edge_t=self.edge_t[lo:hi],
            edge_feats=None if self.edge_feats is None else self.edge_feats[lo:hi],
            node_ids=None if self.node_ids is None else self.node_ids[nsel],
            node_t=None if self.node_t is None else self.node_t[nsel],
            node_feats=None if self.node_feats is None else self.node_feats[nsel],
            eid_offset=self.eid_offset + lo,
        )

    # ------------------------------------------------------------------
    # Time index (binary search over the cached sorted timestamp array)
    # ------------------------------------------------------------------
    def edge_range(self, t_lo: Optional[int], t_hi: Optional[int]) -> Tuple[int, int]:
        """Edge-event index range with t in [t_lo, t_hi). O(log E)."""
        lo = 0 if t_lo is None else int(np.searchsorted(self.edge_t, t_lo, "left"))
        hi = (
            self.num_edge_events
            if t_hi is None
            else int(np.searchsorted(self.edge_t, t_hi, "left"))
        )
        return lo, hi

    def node_event_range(self, t_lo, t_hi) -> Tuple[int, int]:
        """Node-event index range with t in [t_lo, t_hi). O(log #events)."""
        if self.node_t is None:
            return 0, 0
        lo = 0 if t_lo is None else int(np.searchsorted(self.node_t, t_lo, "left"))
        hi = (
            len(self.node_t)
            if t_hi is None
            else int(np.searchsorted(self.node_t, t_hi, "left"))
        )
        return lo, hi

    # ------------------------------------------------------------------
    # Discretization (delegates; see core/discretize.py)
    # ------------------------------------------------------------------
    def discretize(
        self,
        granularity: TimeDelta | str,
        reduce: str = "first",
        backend: str = "numpy",
    ) -> "DGData":
        """Coarsen to ``granularity`` via ``psi_r`` (``core/discretize.py``)."""
        from repro.core.discretize import discretize as _disc

        return _disc(self, TimeDelta.coerce(granularity), reduce=reduce, backend=backend)

    def to_snapshots(
        self,
        granularity: TimeDelta | str,
        capacity: Optional[int] = None,
        device=None,
    ) -> "SnapshotTensor":
        """Tensorize this storage into a device-resident ``SnapshotTensor``
        (delegates to ``core.loader.snapshot_tensor``)."""
        from repro.core.loader import snapshot_tensor

        return snapshot_tensor(self, granularity, capacity=capacity,
                               device=device)


@dataclasses.dataclass(frozen=True)
class SnapshotTensor:
    """Device-resident DTDG view: the discretized stream as padded tensors.

    Built **once** per (storage, granularity) by
    ``core.loader.snapshot_tensor`` — the jitted ``discretize_edges_padded``
    collapses duplicate ``(tick, src, dst)`` classes on device and a second
    jitted scatter lays the classes out snapshot-major:

      ``src``/``dst`` : (T, capacity) int32, zero where padded
      ``mask``        : (T, capacity) bool edge-validity mask
      ``counts``      : (T,) int32 valid edges per snapshot (empty windows
                        are materialized as all-False rows, matching the
                        loader's ``emit_empty=True`` iterate-by-time mode)

    Row ``i`` is the snapshot ``G|_[(t0+i)*k, (t0+i+1)*k)`` of the source
    stream (``k`` native ticks per snapshot). Because every row has the
    same static shape, a whole epoch over the view is one ``lax.scan`` —
    the compiled DTDG pipeline (``docs/dtdg.md``).
    """

    src: object
    dst: object
    mask: object
    counts: object
    t0: int
    ticks: int
    unit: TimeDelta
    num_nodes: int

    @property
    def num_snapshots(self) -> int:
        """T: number of snapshot rows (including empty windows)."""
        return int(self.src.shape[0])

    @property
    def capacity(self) -> int:
        """Fixed per-snapshot edge capacity (padded width)."""
        return int(self.src.shape[1])

    def row(self, i: int) -> dict:
        """One snapshot's padded arrays: ``{src, dst, snap_mask}``."""
        return {"src": self.src[i], "dst": self.dst[i],
                "snap_mask": self.mask[i]}

    def row_of_time(self, t: int) -> int:
        """Snapshot row index containing native-granularity time ``t``."""
        return int(t) // self.ticks - self.t0

    def negatives(self, seed: int, num_negatives: int, rows=None):
        """Per-snapshot negative destinations ``(R, capacity, m)`` for
        ``rows`` (default: every snapshot); pure in
        ``(seed, m, row)`` — see ``core.negatives.snapshot_negatives``."""
        import numpy as _np

        from repro.core.negatives import snapshot_negatives

        if rows is None:
            rows = _np.arange(self.num_snapshots)
        return snapshot_negatives(seed, self.num_nodes, self.capacity,
                                  num_negatives, rows)


class DGraph:
    """Lightweight, concurrency-safe view over a ``DGData`` storage.

    Tracks time boundaries ``[t_lo, t_hi)`` and the iteration granularity.
    Creating or slicing a view never copies event arrays.
    """

    __slots__ = ("data", "t_lo", "t_hi", "granularity", "device")

    def __init__(
        self,
        data: DGData,
        t_lo: Optional[int] = None,
        t_hi: Optional[int] = None,
        granularity: Optional[TimeDelta | str] = None,
        device: str = "cpu",
    ):
        self.data = data
        span = data.time_span
        self.t_lo = span[0] if t_lo is None else int(t_lo)
        self.t_hi = span[1] + 1 if t_hi is None else int(t_hi)
        g = data.granularity if granularity is None else TimeDelta.coerce(granularity)
        if not g.is_event_ordered and not data.granularity.is_event_ordered:
            if not g.is_coarser_or_equal(data.granularity):
                raise ValueError(
                    f"view granularity {g} must be >= native {data.granularity}"
                )
        self.granularity = g
        self.device = device

    # -- slicing -----------------------------------------------------------
    def slice_time(self, t_lo: int, t_hi: int) -> "DGraph":
        """Temporal sub-graph G|_[t_lo, t_hi). O(1)."""
        return DGraph(
            self.data,
            max(self.t_lo, t_lo),
            min(self.t_hi, t_hi),
            self.granularity,
            self.device,
        )

    # -- statistics ---------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.data.num_nodes

    @property
    def num_edge_events(self) -> int:
        lo, hi = self.data.edge_range(self.t_lo, self.t_hi)
        return hi - lo

    @property
    def num_node_events(self) -> int:
        lo, hi = self.data.node_event_range(self.t_lo, self.t_hi)
        return hi - lo

    def edge_slice(self) -> Tuple[int, int]:
        """Edge-event index range [lo, hi) of this view in its storage."""
        return self.data.edge_range(self.t_lo, self.t_hi)

    # -- materialization -----------------------------------------------------
    def materialize(self, lo: Optional[int] = None, hi: Optional[int] = None) -> dict:
        """Raw event arrays for edge-index range [lo, hi) within the view."""
        vlo, vhi = self.edge_slice()
        lo = vlo if lo is None else max(vlo, lo)
        hi = vhi if hi is None else min(vhi, hi)
        d = self.data
        out = {
            "src": d.src[lo:hi],
            "dst": d.dst[lo:hi],
            "time": d.edge_t[lo:hi],
        }
        if d.edge_feats is not None:
            out["edge_feats"] = d.edge_feats[lo:hi]
        if d.node_ids is not None and hi > lo:
            t0 = int(d.edge_t[lo]) if hi > lo else self.t_lo
            t1 = int(d.edge_t[hi - 1]) + 1 if hi > lo else self.t_hi
            nlo, nhi = d.node_event_range(t0, t1)
            out["node_event_ids"] = d.node_ids[nlo:nhi]
            out["node_event_time"] = d.node_t[nlo:nhi]
            if d.node_feats is not None:
                out["node_event_feats"] = d.node_feats[nlo:nhi]
        return out

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"DGraph(nodes={self.num_nodes}, edges={self.num_edge_events}, "
            f"t=[{self.t_lo},{self.t_hi}), gran={self.granularity})"
        )
