"""Hooks and hook management (paper Defs. 3.7-3.8).

A hook ``phi_{R,P}`` is a transformation on a materialized batch that
declares a typed contract: the attributes it *requires* on input and the
attributes it *produces*. A set of hooks is a valid *recipe* iff the induced
dependency graph is acyclic and every requirement is satisfied by some
earlier producer (or by the base materialization); recipes are executed in
topological order.

The ``HookManager`` owns hook state, resolves the ordering once at build
time (invalid recipes fail fast with a precise diagnostic), supports keyed
activation groups (e.g. ``train`` vs ``eval`` hooks), and exposes a single
``reset_state`` for all stateful hooks. The hook/recipe formalism and the
``state_dict`` checkpoint contract are documented in
``docs/architecture.md``.
"""

from __future__ import annotations

from graphlib import CycleError, TopologicalSorter
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Sequence

from repro.core.batch import Batch

# Attributes present on every materialized batch before any hook runs.
BASE_ATTRS: FrozenSet[str] = frozenset({"src", "dst", "time"})


class Hook:
    """Base hook. Subclass and implement ``__call__``; declare the contract
    via class attributes or constructor arguments.

    ``name`` is the display identity (diagnostics, ``repr``);
    ``state_key`` is the checkpoint identity used by
    ``HookManager.state_dict`` and defaults to ``name``. Hooks whose state
    is interchangeable with a twin implementation (e.g. host/device sampler
    pairs) share a ``state_key`` so checkpoints restore across pipeline
    flavors, without masquerading in error messages.
    """

    requires: FrozenSet[str] = frozenset()
    produces: FrozenSet[str] = frozenset()
    name: str = ""

    def __init__(
        self,
        requires: Optional[Iterable[str]] = None,
        produces: Optional[Iterable[str]] = None,
        name: Optional[str] = None,
        state_key: Optional[str] = None,
    ):
        if requires is not None:
            self.requires = frozenset(requires)
        else:
            self.requires = frozenset(type(self).requires)
        if produces is not None:
            self.produces = frozenset(produces)
        else:
            self.produces = frozenset(type(self).produces)
        self.name = name or type(self).__name__
        self.state_key = state_key or self.name

    # Stateful hooks override these.
    def reset_state(self) -> None:
        pass

    # Checkpointable hooks override these (return/accept a dict of numpy
    # arrays; the default is stateless).
    def state_dict(self) -> Dict:
        return {}

    def load_state_dict(self, state: Dict) -> None:
        if state:
            raise ValueError(
                f"hook {self.name!r} is stateless but got state {sorted(state)}"
            )

    def __call__(self, batch: Batch) -> Batch:  # pragma: no cover - abstract
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.name}(R={sorted(self.requires)}, P={sorted(self.produces)})"


class LambdaHook(Hook):
    """Wrap a plain function as a hook."""

    def __init__(
        self,
        fn: Callable[[Batch], Batch],
        requires: Iterable[str] = (),
        produces: Iterable[str] = (),
        name: Optional[str] = None,
    ):
        super().__init__(requires, produces, name or getattr(fn, "__name__", "lambda"))
        self._fn = fn

    def __call__(self, batch: Batch) -> Batch:
        return self._fn(batch)


class RecipeError(ValueError):
    """Invalid hook recipe: unsatisfied requirement or dependency cycle."""


def resolve_order(hooks: Sequence[Hook], base_attrs: FrozenSet[str] = BASE_ATTRS) -> List[Hook]:
    """Topologically order ``hooks`` by their R/P contracts (paper Eq. 3).

    ``phi_i -> phi_j`` iff ``P_i ∩ R_j != ∅``. Raises ``RecipeError`` if a
    requirement is produced by no hook (and absent from ``base_attrs``) or if
    the dependency graph is cyclic. Ties are broken by registration order so
    execution is deterministic.
    """
    produced_by: Dict[str, List[int]] = {}
    for i, h in enumerate(hooks):
        for attr in h.produces:
            produced_by.setdefault(attr, []).append(i)

    all_available = set(base_attrs) | set(produced_by)
    for h in hooks:
        missing = h.requires - all_available
        if missing:
            raise RecipeError(
                f"hook {h.name!r} requires {sorted(missing)} which no hook "
                f"produces and is not a base attribute {sorted(base_attrs)}"
            )

    ts: TopologicalSorter = TopologicalSorter()
    for j, h in enumerate(hooks):
        deps = set()
        for attr in h.requires:
            for i in produced_by.get(attr, []):
                if i != j:
                    deps.add(i)
        ts.add(j, *sorted(deps))
    try:
        ts.prepare()
    except CycleError as e:
        cyc = [hooks[i].name for i in e.args[1] if isinstance(i, int)]
        raise RecipeError(f"hook dependency cycle: {cyc}") from e

    # Kahn's algorithm with registration-order tie-breaking for determinism.
    order: List[int] = []
    ready = sorted(ts.get_ready())
    while ready:
        n = ready.pop(0)
        order.append(n)
        ts.done(n)
        ready = sorted(set(ready) | set(ts.get_ready()))
    return [hooks[i] for i in order]


class HookManager:
    """Registry + executor for hooks, with keyed activation groups.

    Hooks are registered under string keys (default ``"shared"``); shared
    hooks always run. ``activate(key)`` selects which keyed group is live,
    e.g. negative-sampling under ``"train"`` vs fixed negatives under
    ``"eval"``. Ordering is (re)resolved lazily and cached per active key.
    """

    SHARED_KEY = "shared"

    def __init__(self, base_attrs: FrozenSet[str] = BASE_ATTRS):
        self._groups: Dict[str, List[Hook]] = {self.SHARED_KEY: []}
        self._active: Optional[str] = None
        self._order_cache: Dict[Optional[str], List[Hook]] = {}
        self._base_attrs = base_attrs

    # -- registration -------------------------------------------------------
    def register(self, hook: Hook, key: str = SHARED_KEY) -> "HookManager":
        self._groups.setdefault(key, []).append(hook)
        self._order_cache.clear()
        # Validate eagerly (optimistically) so a clearly-bad recipe fails at
        # registration time: every requirement must be producible by *some*
        # registered hook in any group, or be a base attribute. Strict
        # per-activation validation happens at resolve time.
        available = set(self._base_attrs)
        for group in self._groups.values():
            for h in group:
                available |= h.produces
        missing = hook.requires - available
        if missing:
            raise RecipeError(
                f"hook {hook.name!r} requires {sorted(missing)} which no "
                f"registered hook produces and is not a base attribute"
            )
        return self

    def register_all(self, hooks: Iterable[Hook], key: str = SHARED_KEY) -> "HookManager":
        for h in hooks:
            self.register(h, key)
        return self

    @property
    def keys(self) -> List[str]:
        return [k for k in self._groups if k != self.SHARED_KEY]

    def hooks(self, key: Optional[str] = None) -> List[Hook]:
        out = list(self._groups[self.SHARED_KEY])
        if key is not None:
            out += self._groups.get(key, [])
        return out

    # -- activation ----------------------------------------------------------
    def activate(self, key: str) -> "_Activation":
        if key != self.SHARED_KEY and key not in self._groups:
            # Activating an empty group is allowed (only shared hooks run).
            self._groups.setdefault(key, [])
            self._order_cache.clear()
        return _Activation(self, key)

    @property
    def active_key(self) -> Optional[str]:
        return self._active

    # -- execution ------------------------------------------------------------
    def _resolve(self, key: Optional[str]) -> List[Hook]:
        if key not in self._order_cache:
            self._order_cache[key] = resolve_order(self.hooks(key), self._base_attrs)
        return self._order_cache[key]

    def execute(self, batch: Batch) -> Batch:
        for hook in self._resolve(self._active):
            hook.require_ok = batch.require(*hook.requires)  # runtime contract
            batch = hook(batch)
            missing = hook.produces - batch.attrs
            if missing:
                raise RecipeError(
                    f"hook {hook.name!r} declared produces={sorted(hook.produces)} "
                    f"but did not produce {sorted(missing)}"
                )
        return batch

    # -- state ---------------------------------------------------------------
    def reset_state(self) -> None:
        """Single API to clear the state of all registered hooks (paper §4)."""
        for group in self._groups.values():
            for hook in group:
                hook.reset_state()

    def state_dict(self) -> Dict[str, Dict]:
        """Collect every stateful hook's state, keyed
        ``<group>/<idx>/<state_key>`` (registration position makes keys
        stable across rebuilds; ``state_key`` — not display ``name`` — so
        host/device hook twins interchange). Leaves are numpy arrays, so the
        result drops straight into ``distributed.checkpoint.save``."""
        out: Dict[str, Dict] = {}
        for key, group in self._groups.items():
            for i, hook in enumerate(group):
                state = hook.state_dict()
                if state:
                    out[f"{key}/{i}/{hook.state_key}"] = state
        return out

    def load_state_dict(self, state: Dict[str, Dict]) -> None:
        """Restore hook states collected by ``state_dict`` (matched by
        ``<group>/<idx>/<state_key>``, falling back to the display name for
        checkpoints written before ``state_key`` existed); unmatched
        entries raise."""
        seen = set()
        for key, group in self._groups.items():
            for i, hook in enumerate(group):
                for k in (f"{key}/{i}/{hook.state_key}",
                          f"{key}/{i}/{hook.name}"):
                    if k in state and k not in seen:
                        hook.load_state_dict(state[k])
                        seen.add(k)
                        break
        missing = set(state) - seen
        if missing:
            raise KeyError(f"no registered hook matches state {sorted(missing)}")


class _Activation:
    """Context manager for ``with manager.activate('train'):``."""

    def __init__(self, manager: HookManager, key: str):
        self._m = manager
        self._key = key
        self._prev: Optional[str] = None

    def __enter__(self) -> HookManager:
        self._prev = self._m._active
        self._m._active = self._key
        return self._m

    def __exit__(self, *exc) -> None:
        self._m._active = self._prev
