"""Concrete hook library (paper Table 2): neighbor sampling, negative edge
construction, TGB-style evaluation negatives, device transfer, padding, and
analytics (density-of-states estimation).

All hooks produce fixed-shape tensors (padded + masked) so the jitted model
steps compile exactly once per shape. Sampling hooks come in two flavors:

  * ``RecencyNeighborHook``       — host numpy circular buffers (the seed
                                    implementation; parity oracle).
  * ``DeviceRecencyNeighborHook`` — the ``device_sampling=True`` pipeline:
                                    buffers live on the accelerator as a JAX
                                    pytree (``DeviceRecencySampler``) and
                                    both the batch insert and the K-recent
                                    gather run jit-compiled on device, so
                                    neighbor tensors are born device-resident
                                    and never cross PCIe.

The uniform samplers pair the same way: ``UniformNeighborHook`` (host CSR)
and ``DeviceUniformNeighborHook`` (device CSR + jitted composite-key
searchsorted). Hook ordering/contracts and the checkpoint story live in
``docs/architecture.md``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.batch import Batch
from repro.core.device_sampler import DeviceRecencySampler
from repro.core.hooks import Hook
from repro.core.negatives import NegativeEdgeSampler
from repro.core.sampler import RecencySampler, UniformSampler


def _jnp():
    """Lazy ``jax.numpy`` accessor for array-module dispatch in hooks that
    serve both host (numpy) and device (JAX) sampler twins."""
    import jax.numpy as jnp

    return jnp


from collections import OrderedDict  # noqa: E402

_EDGE_TABLE_CACHE: OrderedDict = OrderedDict()
_EDGE_TABLE_CACHE_MAX = 8


def device_edge_table(feats, sharding=None):
    """Device-resident ``float32`` view of an edge-feature storage array,
    cached by storage identity.

    Epoch resets and mesh re-stagings rebuild hook pipelines over the
    *same* host storage array; re-transferring the full ``(E, d)`` table
    each time is pure waste (ROADMAP "TPU memory niceties"). The cache key
    is ``(id(storage), shape, dtype, sharding)`` and each entry pins the
    source array — its ``id`` cannot be recycled while the entry lives, so
    a hit is guaranteed to be the same storage — with a small FIFO bound
    keeping the pin set tiny. JAX arrays pass through (re-placed only when
    a ``sharding`` is requested).
    """
    import jax
    import jax.numpy as jnp

    if isinstance(feats, jax.Array):
        return feats if sharding is None else jax.device_put(feats, sharding)
    arr = np.asarray(feats)
    key = (id(feats), arr.shape, arr.dtype.str, sharding)
    entry = _EDGE_TABLE_CACHE.get(key)
    if entry is not None and entry[0] is feats:
        _EDGE_TABLE_CACHE.move_to_end(key)
        return entry[1]
    table = jnp.asarray(arr, jnp.float32)
    if sharding is not None:
        table = jax.device_put(table, sharding)
    _EDGE_TABLE_CACHE[key] = (feats, table)
    while len(_EDGE_TABLE_CACHE) > _EDGE_TABLE_CACHE_MAX:
        _EDGE_TABLE_CACHE.popitem(last=False)
    return table


class NegativeEdgeHook(Hook):
    """Produces ``neg``: (B, num_negatives) corrupted destinations."""

    def __init__(self, num_nodes: int, num_negatives: int = 1,
                 strategy: str = "random", seed: int = 0,
                 dst_pool: Optional[np.ndarray] = None):
        super().__init__(requires={"src", "dst", "time"}, produces={"neg"})
        self._sampler = NegativeEdgeSampler(
            num_nodes, strategy=strategy, num_negatives=num_negatives,
            seed=seed, dst_pool=dst_pool,
        )

    def reset_state(self) -> None:
        """Reset the negative sampler's RNG and observed-destination pool."""
        self._sampler.reset_state()

    def __call__(self, batch: Batch) -> Batch:
        src, dst, t = batch["src"], batch["dst"], batch["time"]
        batch["neg"] = self._sampler.sample(src, dst, t)
        if "batch_mask" in batch:
            m = batch["batch_mask"]
            self._sampler.observe(src[m], dst[m])
        else:
            self._sampler.observe(src, dst)
        return batch


class TGBEvalNegativesHook(Hook):
    """One-vs-many evaluation negatives (TGB protocol).

    Deterministic per (seed, batch_counter) so every epoch ranks positives
    against the same negative sets. Produces ``neg``: (B, num_negatives).
    """

    def __init__(self, num_nodes: int, num_negatives: int = 100, seed: int = 0,
                 dst_pool: Optional[np.ndarray] = None):
        super().__init__(requires={"src", "dst", "time"}, produces={"neg"})
        self.num_negatives = num_negatives
        self._seed = seed
        self._counter = 0
        self._pool = (
            np.arange(num_nodes, dtype=np.int64) if dst_pool is None
            else np.asarray(dst_pool, dtype=np.int64)
        )

    def reset_state(self) -> None:
        """Rewind the per-batch counter so eval negatives replay exactly."""
        self._counter = 0

    def __call__(self, batch: Batch) -> Batch:
        rng = np.random.default_rng((self._seed, self._counter))
        self._counter += 1
        B = len(batch["src"])
        batch["neg"] = rng.choice(self._pool, size=(B, self.num_negatives)).astype(np.int64)
        return batch


class RecencyNeighborHook(Hook):
    """Temporal neighbor sampling from a recency circular buffer.

    Seeds are the batch's (src, dst[, neg...]) nodes at the batch query
    times. Produces hop-1 (and optionally hop-2) neighborhoods:

      seed_nodes (S,), seed_times (S,),
      nbr_ids/nbr_times/nbr_eids/nbr_mask (S, K)
      [hop2: nbr2_ids/... (S*K, K)]

    With ``dedup=True`` (the paper's batch-level de-duplication, §5.1), the
    unique (node) set is sampled once and results are gathered back to the
    full seed list — the key optimization for one-vs-many eval where the same
    src appears ``1+num_negatives`` times.

    The buffer is updated with the batch's positive edges *after* sampling
    (predict-then-reveal ordering).
    """

    def __init__(self, num_nodes: int, k: int, num_hops: int = 1,
                 include_negatives: bool = True, dedup: bool = True,
                 update_buffer: bool = True):
        if num_hops not in (1, 2):
            raise ValueError("num_hops must be 1 or 2")
        produces = {"seed_nodes", "seed_times", "nbr_ids", "nbr_times",
                    "nbr_eids", "nbr_mask"}
        if num_hops == 2:
            produces |= {"nbr2_ids", "nbr2_times", "nbr2_eids", "nbr2_mask"}
        requires = {"src", "dst", "time"} | ({"neg"} if include_negatives else set())
        super().__init__(requires=requires, produces=produces)
        self.sampler = RecencySampler(num_nodes, k)
        self.k = k
        self.num_hops = num_hops
        self.include_negatives = include_negatives
        self.dedup = dedup
        self.update_buffer = update_buffer

    def reset_state(self) -> None:
        """Clear the host circular buffers (start of an epoch)."""
        self.sampler.reset_state()

    def state_dict(self) -> dict:
        """Checkpoint the sampler buffers (shared host/device contract)."""
        return self.sampler.state_dict()

    def load_state_dict(self, state: dict) -> None:
        """Restore sampler buffers saved by either recency sampler."""
        self.sampler.load_state_dict(state)

    def _seeds(self, batch: Batch):
        src, dst, t = batch["src"], batch["dst"], batch["time"]
        seeds = [src, dst]
        times = [t, t]
        if self.include_negatives and "neg" in batch:
            neg = batch["neg"]  # (B, Nneg)
            seeds.append(neg.reshape(-1))
            times.append(np.repeat(t, neg.shape[1]))
        return np.concatenate(seeds), np.concatenate(times)

    def __call__(self, batch: Batch) -> Batch:
        seed_nodes, seed_times = self._seeds(batch)

        if self.dedup:
            # Batch-level de-duplication: sample once per unique node. Within
            # a batch all queries share the batch time frontier, so one sample
            # per node is exact (buffer state is fixed during sampling).
            uniq, inverse = np.unique(seed_nodes, return_inverse=True)
            blk = self.sampler.sample(uniq)
            sel = inverse
            nbr_ids, nbr_times = blk.nbr_ids[sel], blk.nbr_times[sel]
            nbr_eids, nbr_mask = blk.nbr_eids[sel], blk.mask[sel]
        else:
            blk = self.sampler.sample(seed_nodes)
            nbr_ids, nbr_times = blk.nbr_ids, blk.nbr_times
            nbr_eids, nbr_mask = blk.nbr_eids, blk.mask

        batch["seed_nodes"], batch["seed_times"] = seed_nodes, seed_times
        batch["nbr_ids"], batch["nbr_times"] = nbr_ids, nbr_times
        batch["nbr_eids"], batch["nbr_mask"] = nbr_eids, nbr_mask

        if self.num_hops == 2:
            flat = nbr_ids.reshape(-1)
            safe = np.where(flat >= 0, flat, 0)
            if self.dedup:
                uniq2, inv2 = np.unique(safe, return_inverse=True)
                blk2 = self.sampler.sample(uniq2)
                ids2, t2 = blk2.nbr_ids[inv2], blk2.nbr_times[inv2]
                e2, m2 = blk2.nbr_eids[inv2], blk2.mask[inv2]
            else:
                blk2 = self.sampler.sample(safe)
                ids2, t2, e2, m2 = blk2.nbr_ids, blk2.nbr_times, blk2.nbr_eids, blk2.mask
            pad = (flat < 0)[:, None]
            batch["nbr2_ids"] = np.where(pad, -1, ids2)
            batch["nbr2_times"] = np.where(pad, 0, t2)
            batch["nbr2_eids"] = np.where(pad, -1, e2)
            batch["nbr2_mask"] = np.where(pad, False, m2)

        if self.update_buffer:
            eids = batch.meta.get("eids")
            src, dst, t = batch["src"], batch["dst"], batch["time"]
            if "batch_mask" in batch:  # exclude padded events from state
                m = batch["batch_mask"]
                src, dst, t = src[m], dst[m], t[m]
                eids = None if eids is None else eids[m[: len(eids)]]
            self.sampler.update(src, dst, t, eids)
        return batch


class DeviceRecencyNeighborHook(Hook):
    """Device-resident temporal neighbor sampling (``device_sampling=True``).

    Same contract as ``RecencyNeighborHook`` (hop-1/hop-2 neighborhoods,
    predict-then-reveal buffer updates), but backed by
    ``DeviceRecencySampler``: state stays on the accelerator as a packed
    ``(N+1, K, 3)`` buffer (channels = neighbor id / time / edge id, row N
    the write sink) and both ``update`` and ``sample`` are jit-compiled. The
    produced neighbor tensors are JAX device arrays — the downstream
    ``DeviceTransferHook`` passes them through untouched.

    With ``expose_buffer=True`` (the default) each batch also carries:

      * ``nbr_buf``         — the packed buffer *as sampled*, i.e. the
        pre-update snapshot (JAX arrays are immutable, so stashing the
        reference before the update is a zero-copy snapshot; the sampler is
        built with ``retain_state=True`` so donation never invalidates it).
        This is what the fused TGAT/TGN attention reads so the per-seed
        neighbor gather can happen inside the kernel.
      * ``edge_feat_table`` — the raw (E, d_edge) edge-feature storage (only
        when ``edge_feats`` is given), indexed in-kernel by the buffer's
        edge-id channel.

    Differences from the host hook, both deliberate:

      * no batch-level de-duplication — on device the K-recent lookup is a
        single gather, so sampling all ``(2 + num_negatives) * B`` seeds
        directly is cheaper than a host ``np.unique`` round-trip and keeps
        shapes fixed (one XLA compilation per activation key);
      * buffer updates consume the full padded batch plus ``batch_mask`` as
        a validity mask instead of slicing, again for fixed shapes.

    With ``mesh`` the sampler state is partitioned row-wise by node id
    over the mesh's node axis and update/sample run through ``shard_map``
    — same outputs, state scales past one device's HBM. ``expose_buffer``
    defaults off there (the sharded packed layout interleaves per-shard
    sink rows); pass ``expose_buffer=True`` to carry the *sharded* buffer
    on each batch for the shard-aware fused attention path
    (``fused_temporal_layer_sharded``); see ``docs/sharding.md``.
    """

    def __init__(self, num_nodes: int, k: int, num_hops: int = 1,
                 include_negatives: bool = True, update_buffer: bool = True,
                 device=None, expose_buffer: Optional[bool] = None,
                 edge_feats=None, mesh=None, mesh_axis: str = "data"):
        if num_hops not in (1, 2):
            raise ValueError("num_hops must be 1 or 2")
        if mesh is not None and expose_buffer is None:
            # Auto under a mesh: keep the buffer private. The sharded
            # packed layout interleaves per-shard sink rows, so only the
            # shard-aware fused path (``fused_temporal_layer_sharded``
            # inside a shard_map over the node axis) can consume it —
            # pipelines that want it must opt in with expose_buffer=True
            # (CTDGLinkPipeline does when the fused path is enabled; see
            # docs/sharding.md).
            expose_buffer = False
        if expose_buffer is None:
            # Auto: expose wherever a consumer can exist. The fused model
            # path engages on TPU (and in CPU parity tests, where the
            # update already copies); on GPU nothing reads ``nbr_buf`` and
            # exposing it would force retain_state copies instead of the
            # donated in-place buffer update — skip it there. The recipe/
            # trainer can pass an explicit value (e.g. False for models
            # without a fused path).
            import jax

            expose_buffer = jax.default_backend() != "gpu"
        produces = {"seed_nodes", "seed_times", "nbr_ids", "nbr_times",
                    "nbr_eids", "nbr_mask"}
        if num_hops == 2:
            produces |= {"nbr2_ids", "nbr2_times", "nbr2_eids", "nbr2_mask"}
        if expose_buffer:
            produces |= {"nbr_buf"}
            if edge_feats is not None:
                produces |= {"edge_feat_table"}
        requires = {"src", "dst", "time"} | ({"neg"} if include_negatives else set())
        # Shared checkpoint key with the host twin: the sampler state_dicts
        # are interchangeable, so HookManager checkpoint keys must match
        # across device_sampling pipeline flavors (display name stays
        # accurate for diagnostics).
        super().__init__(requires=requires, produces=produces,
                         state_key="RecencyNeighborHook")
        self.sampler = DeviceRecencySampler(num_nodes, k, device=device,
                                            retain_state=expose_buffer,
                                            mesh=mesh, mesh_axis=mesh_axis)
        self.k = k
        self.num_hops = num_hops
        self.include_negatives = include_negatives
        self.update_buffer = update_buffer
        self.expose_buffer = expose_buffer
        self._edge_table = None
        if expose_buffer and edge_feats is not None:
            sh = None
            if mesh is not None:
                # Replicate the table over the whole mesh up front so the
                # sharded steps never re-stage it per invocation.
                from repro.distributed.sharding import replicated_sharding

                sh = replicated_sharding(mesh)
            self._edge_table = device_edge_table(edge_feats, sharding=sh)

    def reset_state(self) -> None:
        """Clear the on-device circular buffers (start of an epoch)."""
        self.sampler.reset_state()

    def state_dict(self) -> dict:
        """Checkpoint the sampler buffers (shared host/device contract)."""
        return self.sampler.state_dict()

    def load_state_dict(self, state: dict) -> None:
        """Restore sampler buffers saved by either recency sampler."""
        self.sampler.load_state_dict(state)

    def __call__(self, batch: Batch) -> Batch:
        """Sample hop-1/2 neighborhoods, expose the pre-update buffer, then
        reveal the batch's positive edges to the sampler."""
        import jax.numpy as jnp

        src, dst, t = batch["src"], batch["dst"], batch["time"]
        if self.expose_buffer:
            # Pre-update snapshot: the state the neighborhoods below are
            # sampled from (predict-then-reveal).
            batch["nbr_buf"] = self.sampler.packed_buffer
            if self._edge_table is not None:
                batch["edge_feat_table"] = self._edge_table
        seeds = [np.asarray(src), np.asarray(dst)]
        times = [np.asarray(t), np.asarray(t)]
        if self.include_negatives and "neg" in batch:
            neg = np.asarray(batch["neg"])  # (B, Nneg)
            seeds.append(neg.reshape(-1))
            times.append(np.repeat(np.asarray(t), neg.shape[1]))
        seed_nodes = np.concatenate(seeds).astype(np.int64)
        seed_times = np.concatenate(times).astype(np.int64)

        blk = self.sampler.sample(seed_nodes)
        batch["seed_nodes"], batch["seed_times"] = seed_nodes, seed_times
        batch["nbr_ids"], batch["nbr_times"] = blk.nbr_ids, blk.nbr_times
        batch["nbr_eids"], batch["nbr_mask"] = blk.nbr_eids, blk.mask

        if self.num_hops == 2:
            flat = blk.nbr_ids.reshape(-1)
            safe = jnp.where(flat >= 0, flat, 0)
            blk2 = self.sampler.sample(safe)
            pad = (flat < 0)[:, None]
            batch["nbr2_ids"] = jnp.where(pad, -1, blk2.nbr_ids)
            batch["nbr2_times"] = jnp.where(pad, 0, blk2.nbr_times)
            batch["nbr2_eids"] = jnp.where(pad, -1, blk2.nbr_eids)
            batch["nbr2_mask"] = jnp.where(pad, False, blk2.mask)

        if self.update_buffer:
            eids = batch.meta.get("eids")
            n = len(np.asarray(src))
            if eids is None:
                eids_full = np.full(n, -1, dtype=np.int64)
            else:
                eids_full = np.full(n, -1, dtype=np.int64)
                eids_full[: len(eids)] = eids
            valid = np.asarray(batch["batch_mask"]) if "batch_mask" in batch \
                else np.ones(n, bool)
            self.sampler.update(np.asarray(src), np.asarray(dst),
                                np.asarray(t), eids_full, valid=valid)
        return batch


class UniformNeighborHook(Hook):
    """Uniform temporal neighbor sampling (requires a pre-built adjacency).

    Seeds are the batch's (src, dst[, neg...]) nodes queried at the batch
    event times; each seed draws K uniform neighbors from its strict past
    (``t < query_t``), so a once-per-split ``build`` over the full stream
    leaks nothing. Stateless across batches except for the reproducible
    draw counter (checkpointed via ``state_dict``).

    With ``num_hops=2`` the hop-1 frontier is sampled recursively: each
    sampled neighbor becomes a hop-2 seed queried at its *own* interaction
    time (strict ``t < t_hop1``, the TGAT temporal-causality convention),
    producing ``nbr2_*`` blocks aligned with the flattened hop-1 frontier —
    rows whose hop-1 slot is padding come back fully masked. The ``S*K``
    frontier is deduplicated at the batch level before the adjacency
    binary search (inside ``UniformSampler.sample``: duplicate
    ``(node, time)`` pairs — ubiquitous in one-vs-many eval shapes, where
    negatives share the positives' neighbors — collapse to one searchsorted
    key each), bit-identically to the direct search.
    """

    def __init__(self, num_nodes: int, k: int, include_negatives: bool = False,
                 seed: int = 0, num_hops: int = 1,
                 checkpoint_adjacency: bool = True):
        if num_hops not in (1, 2):
            raise ValueError("num_hops must be 1 or 2")
        requires = {"src", "dst", "time"} | ({"neg"} if include_negatives else set())
        produces = {"seed_nodes", "seed_times", "nbr_ids", "nbr_times",
                    "nbr_eids", "nbr_mask"}
        if num_hops == 2:
            produces |= {"nbr2_ids", "nbr2_times", "nbr2_eids", "nbr2_mask"}
        super().__init__(requires=requires, produces=produces)
        self.sampler = UniformSampler(num_nodes, k, seed=seed,
                                      checkpoint_adjacency=checkpoint_adjacency)
        self.num_hops = num_hops
        self.include_negatives = include_negatives

    def build(self, src, dst, t, eids=None) -> "UniformNeighborHook":
        """Build the sampler's CSR-by-time adjacency; returns self."""
        self.sampler.build(src, dst, t, eids)
        return self

    def build_from_store(self, store, **kwargs) -> "UniformNeighborHook":
        """Build the adjacency from an ``EventStore`` via the streaming
        two-pass build (O(chunk) resident — ``repro.storage.streaming_csr``);
        returns self. Works for both the host and device hook (each
        sampler implements ``build_from_store``)."""
        self.sampler.build_from_store(store, **kwargs)
        return self

    def reset_state(self) -> None:
        """Rewind the sampler's draw counter (epochs replay exactly)."""
        self.sampler.reset_state()

    def state_dict(self) -> dict:
        """Checkpoint the sampler (shared host/device uniform contract)."""
        return self.sampler.state_dict()

    def load_state_dict(self, state: dict) -> None:
        """Restore state saved by either uniform sampler."""
        self.sampler.load_state_dict(state)

    def __call__(self, batch: Batch) -> Batch:
        """Sample hop-1 uniform temporal neighborhoods for the batch."""
        src, dst, t = batch["src"], batch["dst"], batch["time"]
        seeds = [src, dst]
        times = [t, t]
        if self.include_negatives and "neg" in batch:
            neg = batch["neg"]
            seeds.append(neg.reshape(-1))
            times.append(np.repeat(t, neg.shape[1]))
        seed_nodes, seed_times = np.concatenate(seeds), np.concatenate(times)
        blk = self.sampler.sample(seed_nodes, seed_times)
        batch["seed_nodes"], batch["seed_times"] = seed_nodes, seed_times
        batch["nbr_ids"], batch["nbr_times"] = blk.nbr_ids, blk.nbr_times
        batch["nbr_eids"], batch["nbr_mask"] = blk.nbr_eids, blk.mask

        if self.num_hops == 2:
            # Recursive frontier: hop-1 neighbors become hop-2 seeds queried
            # at their own interaction times (strict past, leak-free).
            xp = np if isinstance(blk.nbr_ids, np.ndarray) else _jnp()
            flat_ids = blk.nbr_ids.reshape(-1)
            flat_t = blk.nbr_times.reshape(-1)
            invalid = flat_ids < 0
            safe = xp.where(invalid, 0, flat_ids)
            blk2 = self.sampler.sample(safe, xp.where(invalid, 0, flat_t))
            pad = invalid[:, None]
            batch["nbr2_ids"] = xp.where(pad, -1, blk2.nbr_ids)
            batch["nbr2_times"] = xp.where(pad, 0, blk2.nbr_times)
            batch["nbr2_eids"] = xp.where(pad, -1, blk2.nbr_eids)
            batch["nbr2_mask"] = xp.where(pad, False, blk2.mask)
        return batch


class DeviceUniformNeighborHook(UniformNeighborHook):
    """Device-resident uniform temporal neighbor sampling
    (``device_sampling=True`` + ``sampler="uniform"``).

    Same contract and seed assembly as ``UniformNeighborHook`` (including
    the ``num_hops=2`` recursive frontier) but backed by
    ``DeviceUniformSampler``: the CSR-by-time adjacency lives on the
    accelerator and sampling is one jitted composite-key ``searchsorted``
    over the whole seed batch — the produced neighbor tensors are born
    device-resident, mirroring ``DeviceRecencyNeighborHook``. With
    ``mesh`` the CSR is split on node boundaries over the mesh and
    sampling runs through ``shard_map`` (see ``docs/sharding.md``).
    """

    def __init__(self, num_nodes: int, k: int, include_negatives: bool = False,
                 seed: int = 0, device=None, num_hops: int = 1,
                 checkpoint_adjacency: bool = True, mesh=None,
                 mesh_axis: str = "data", partition: str = "rows"):
        from repro.core.device_uniform import DeviceUniformSampler

        super().__init__(num_nodes, k, include_negatives=include_negatives,
                         seed=seed, num_hops=num_hops)
        self.sampler = DeviceUniformSampler(
            num_nodes, k, seed=seed, device=device,
            checkpoint_adjacency=checkpoint_adjacency, mesh=mesh,
            mesh_axis=mesh_axis, partition=partition)
        # Shared checkpoint key with the host twin (see
        # DeviceRecencyNeighborHook): state_dicts are interchangeable.
        self.state_key = "UniformNeighborHook"


class SnapshotNegativeHook(Hook):
    """Per-snapshot negative destinations for the DTDG link recipe.

    Produces ``neg``: (capacity, num_negatives) int32 corrupted destinations
    for the batch's (predicted) snapshot. Draws are a pure function of
    ``(seed, num_negatives, snapshot row)`` via
    ``core.negatives.snapshot_negatives`` — the same function the
    scan-compiled epoch uses to pre-draw every snapshot at once — so the
    hook path and the scanned path are bit-identical (see ``docs/dtdg.md``).

    The snapshot row comes from ``batch.meta['snapshot_row']`` when present
    (how ``SnapshotLinkTrainer`` drives the hook — resume correctness then
    follows from the trainer's checkpointed snapshot cursor plus the
    row-pure draws). For standalone recipe use without row metadata, an
    internal cursor advances one row per call; ``seek(row)`` positions it
    and ``state_dict`` checkpoints it.
    """

    def __init__(self, num_nodes: int, capacity: int, num_negatives: int = 1,
                 seed: int = 0):
        super().__init__(requires={"src"}, produces={"neg"})
        self.num_nodes = int(num_nodes)
        self.capacity = int(capacity)
        self.num_negatives = int(num_negatives)
        self._seed = int(seed)
        self._cursor = 0

    def seek(self, row: int) -> None:
        """Position the cursor at snapshot ``row`` (split boundaries)."""
        self._cursor = int(row)

    def reset_state(self) -> None:
        """Rewind the snapshot cursor (start of an epoch)."""
        self._cursor = 0

    def state_dict(self) -> dict:
        """Checkpoint the snapshot cursor (draws are cursor-derived)."""
        return {"cursor": np.int64(self._cursor)}

    def load_state_dict(self, state: dict) -> None:
        """Restore the snapshot cursor."""
        self._cursor = int(state["cursor"])

    def __call__(self, batch: Batch) -> Batch:
        """Attach this snapshot's deterministic negative draws."""
        from repro.core.negatives import snapshot_negatives

        row = int(batch.meta.get("snapshot_row", self._cursor))
        batch["neg"] = snapshot_negatives(
            self._seed, self.num_nodes, self.capacity, self.num_negatives,
            [row],
        )[0]
        self._cursor = row + 1
        return batch


class EdgeFeatureLookupHook(Hook):
    """Produces ``<prefix>_feats``: gather stored edge features for sampled
    neighbor edge ids (zeros where padded / featureless)."""

    def __init__(self, edge_feats: Optional[np.ndarray], feat_dim: int,
                 prefix: str = "nbr"):
        super().__init__(
            requires={f"{prefix}_eids"}, produces={f"{prefix}_feats"}
        )
        self._feats = edge_feats
        self._dim = feat_dim
        self._prefix = prefix

    def __call__(self, batch: Batch) -> Batch:
        eids = batch[f"{self._prefix}_eids"]
        if isinstance(eids, np.ndarray):
            out = np.zeros(eids.shape + (self._dim,), dtype=np.float32)
            if self._feats is not None:
                ok = eids >= 0
                out[ok] = self._feats[eids[ok]]
        else:  # device-resident eids (device-sampling pipeline): jnp gather
            import jax.numpy as jnp

            if self._feats is None:
                out = jnp.zeros(eids.shape + (self._dim,), jnp.float32)
            else:
                if not hasattr(self, "_feats_dev"):
                    self._feats_dev = device_edge_table(self._feats)
                safe = jnp.maximum(eids, 0)
                out = jnp.where((eids >= 0)[..., None],
                                self._feats_dev[safe], 0.0)
        batch[f"{self._prefix}_feats"] = out
        return batch


class PadBatchHook(Hook):
    """Pads event tensors to a fixed batch size and emits ``batch_mask`` so
    every training step has identical shapes (one XLA compilation)."""

    PADDABLE = ("src", "dst", "time", "neg", "edge_feats", "labels")

    def __init__(self, batch_size: int):
        super().__init__(requires={"src"}, produces={"batch_mask"})
        self.batch_size = batch_size

    def __call__(self, batch: Batch) -> Batch:
        n = len(batch["src"])
        pad = self.batch_size - n
        if pad < 0:
            raise ValueError(f"batch of {n} exceeds fixed size {self.batch_size}")
        mask = np.zeros(self.batch_size, dtype=bool)
        mask[:n] = True
        for key in self.PADDABLE:
            if key in batch:
                v = batch[key]
                widths = [(0, pad)] + [(0, 0)] * (v.ndim - 1)
                batch[key] = np.pad(v, widths)
        batch["batch_mask"] = mask
        return batch


def stage_batch(batch: Batch, device=None, pool=None) -> Batch:
    """Ship every host numpy attribute of ``batch`` to ``device`` (int64
    narrowed to int32 for the jitted models); arrays already on device pass
    through. ``device`` may be a concrete device or any
    ``jax.sharding.Sharding`` (the sharded sampling pipeline passes the
    mesh-replicated ``NamedSharding``). Shared by ``DeviceTransferHook``
    and ``PrefetchLoader`` so the transfer/narrowing policy lives in one
    place.

    ``pool`` (a ``core.loader._HostStagingPool``) routes each array through
    a reusable host staging buffer first, and — off CPU only — issues the
    transfer with ``donate=True`` so the runtime may recycle the staged
    source immediately (on CPU, donation zero-copy aliases the source, so a
    reused buffer must never be donated)."""
    import jax

    dev = device or jax.devices()[0]
    donate = pool is not None and jax.default_backend() != "cpu"
    for key in list(batch.keys()):
        v = batch[key]
        if isinstance(v, np.ndarray):
            if pool is not None:
                v = pool.stage(key, v)
            elif v.dtype == np.int64:
                v = v.astype(np.int32)
            batch[key] = jax.device_put(v, dev, donate=donate)
            if pool is not None:
                # Let the slot's next rewrite wait for this transfer.
                pool.note(key, batch[key])
    return batch


class DeviceTransferHook(Hook):
    """Moves all array attributes to a JAX device (paper Table 2: R=∅, P=∅).

    Register last; ordering among contract-free hooks follows registration.
    """

    def __init__(self, device=None):
        super().__init__(requires=set(), produces=set())
        self._device = device

    def __call__(self, batch: Batch) -> Batch:
        return stage_batch(batch, self._device)


class DOSEstimateHook(Hook):
    """Analytics: spectral density-of-states estimate of the batch's
    interaction graph via Hutchinson moment estimation (paper Fig. 3 recipe).

    Produces ``dos``: (num_moments,) Chebyshev moment estimates of the
    normalized adjacency spectrum.
    """

    def __init__(self, num_nodes: int, num_moments: int = 10, num_probes: int = 4,
                 seed: int = 0):
        super().__init__(requires={"src", "dst"}, produces={"dos"})
        self.num_nodes = num_nodes
        self.num_moments = num_moments
        self.num_probes = num_probes
        self._rng = np.random.default_rng(seed)

    def reset_state(self) -> None:
        """Stateless across epochs (probe RNG deliberately persists)."""
        pass

    def __call__(self, batch: Batch) -> Batch:
        src, dst = batch["src"], batch["dst"]
        nodes = np.unique(np.concatenate([src, dst]))
        n = len(nodes)
        if n == 0:
            batch["dos"] = np.zeros(self.num_moments, dtype=np.float32)
            return batch
        remap = {int(u): i for i, u in enumerate(nodes)}
        r = np.array([remap[int(u)] for u in src])
        c = np.array([remap[int(u)] for u in dst])
        deg = np.bincount(np.concatenate([r, c]), minlength=n).astype(np.float64)
        dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))

        def matvec(x):
            y = np.zeros_like(x)
            w = dinv[r] * dinv[c]
            np.add.at(y, r, w[:, None] * x[c])
            np.add.at(y, c, w[:, None] * x[r])
            return y

        z = self._rng.choice([-1.0, 1.0], size=(n, self.num_probes))
        tkm1, tk = z, matvec(z)
        moments = [float((z * tkm1).sum() / (n * self.num_probes)),
                   float((z * tk).sum() / (n * self.num_probes))]
        for _ in range(self.num_moments - 2):
            tkp1 = 2.0 * matvec(tk) - tkm1
            moments.append(float((z * tkp1).sum() / (n * self.num_probes)))
            tkm1, tk = tk, tkp1
        batch["dos"] = np.asarray(moments[: self.num_moments], dtype=np.float32)
        return batch
