"""Unified CTDG/DTDG data loading (paper Defs. 3.3-3.4, Fig. 2).

``DGDataLoader`` iterates a ``DGraph`` view either

  * **by events** (CTDG): fixed event-count batches under the event-ordered
    granularity, or
  * **by time** (DTDG): fixed wall-clock windows of the view's (coarser)
    granularity — batches are snapshots ``G|_[t_i, t_i + tau_hat)``; empty
    windows can be emitted or skipped.

Each batch is materialized from storage, passed through the ``HookManager``
pipeline, and returned as a ``Batch``.

``PrefetchLoader`` overlaps batch preparation with device compute: a
background thread runs the inner loader (materialization + the full hook
pipeline) and stages each batch's arrays onto the device with
``jax.device_put`` while the jitted train step consumes the previous batch.
A bounded queue (default depth 2 = double buffering) provides back-pressure
so at most ``prefetch`` prepared batches are in flight; hook state stays
correct because the hook pipeline still executes strictly sequentially, just
one batch ahead of the consumer. This is the loader half of the
``SamplerSpec(device=True)`` pipeline in ``train.loop``. The staging
model is documented in ``docs/architecture.md``.

``snapshot_tensor`` is the DTDG counterpart of loading: instead of
iterating host batches, it tensorizes the whole discretized stream once
into the device-resident ``SnapshotTensor`` view (padded ``(T, capacity)``
src/dst/mask arrays) that the scan-compiled snapshot trainer consumes —
see ``docs/dtdg.md``.
"""

from __future__ import annotations

import queue
import threading
import time
from functools import partial
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import Batch
from repro.core.graph import DGData, DGraph, SnapshotTensor
from repro.core.granularity import TimeDelta
from repro.core.hooks import HookManager


class DGDataLoader:
    """Iterate a ``DGraph`` view as hook-processed ``Batch``es.

    CTDG mode (``batch_size``): fixed event-count batches in stream order.
    DTDG mode (``batch_unit``): fixed time windows (snapshots) of a real-
    time granularity coarser-or-equal to the view's native unit. Each
    materialized batch is passed through ``hook_manager`` (when given)
    before being yielded. See ``docs/architecture.md``.
    """

    def __init__(
        self,
        dg: DGraph,
        hook_manager: Optional[HookManager] = None,
        batch_size: Optional[int] = 200,
        batch_unit: Optional[TimeDelta | str] = None,
        drop_last: bool = False,
        emit_empty: bool = False,
        window_ticks: int = 1,
        on_batch=None,
    ):
        """Iterate ``dg``.

        Exactly one of ``batch_size`` (iterate-by-events) or ``batch_unit``
        (iterate-by-time) must be set. ``window_ticks`` scales the time
        window (e.g. unit='h', window_ticks=6 -> 6-hour snapshots).
        ``on_batch`` (no-arg callable) runs after each batch has been
        hook-processed and handed off — the storage layer passes
        ``MmapStore.release`` here so an epoch over a memory-mapped
        stream keeps O(window) resident pages (``docs/storage.md``);
        hooks copy everything they keep, so dropped pages are safe.
        """
        if (batch_size is None) == (batch_unit is None):
            raise ValueError("set exactly one of batch_size / batch_unit")
        self.dg = dg
        self.manager = hook_manager
        self.on_batch = on_batch
        self.batch_size = batch_size
        self.drop_last = drop_last
        self.emit_empty = emit_empty
        self.window_ticks = window_ticks
        if batch_unit is not None:
            unit = TimeDelta.coerce(batch_unit)
            native = dg.data.granularity
            if native.is_event_ordered:
                raise ValueError(
                    "iterate-by-time requires a real-time native granularity; "
                    "this graph is event-ordered (paper §3)"
                )
            if not unit.is_coarser_or_equal(native):
                raise ValueError(f"batch unit {unit} must be >= native {native}")
            self.batch_unit = unit
            self._ticks = unit.ticks_per(native) * window_ticks
        else:
            self.batch_unit = None
            self._ticks = None

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        """Number of batches (event batches or time windows) to be yielded;
        for time iteration this is an upper bound when windows can be
        empty and ``emit_empty=False``."""
        if self.batch_size is not None:
            n = self.dg.num_edge_events
            full, rem = divmod(n, self.batch_size)
            return full if (self.drop_last or rem == 0) else full + 1
        span = self.dg.t_hi - self.dg.t_lo
        return int(np.ceil(span / self._ticks))

    def __iter__(self) -> Iterator[Batch]:
        if self.batch_size is not None:
            yield from self._iter_events()
        else:
            yield from self._iter_time()

    # -- CTDG: fixed event count ----------------------------------------
    def _iter_events(self) -> Iterator[Batch]:
        lo, hi = self.dg.edge_slice()
        for start in range(lo, hi, self.batch_size):
            stop = min(start + self.batch_size, hi)
            if self.drop_last and stop - start < self.batch_size:
                break
            batch = self._materialize(start, stop)
            yield self._run_hooks(batch)
            if self.on_batch is not None:
                self.on_batch()

    # -- DTDG: fixed time window ------------------------------------------
    def _iter_time(self) -> Iterator[Batch]:
        data = self.dg.data
        t = self.dg.t_lo
        while t < self.dg.t_hi:
            t_next = min(t + self._ticks, self.dg.t_hi)
            lo, hi = data.edge_range(t, t_next)
            if hi > lo or self.emit_empty:
                batch = self._materialize(lo, hi, window=(t, t_next))
                yield self._run_hooks(batch)
                if self.on_batch is not None:
                    self.on_batch()
            t = t_next

    # ------------------------------------------------------------------
    def _materialize(self, lo: int, hi: int, window=None) -> Batch:
        raw = self.dg.materialize(lo, hi)
        meta = {
            # Global event ids (sliced splits carry their root offset), so
            # eid-keyed edge-feature lookups are correct on any split.
            "eids": np.arange(lo, hi, dtype=np.int64)
            + getattr(self.dg.data, "eid_offset", 0),
            "window": window,
            "granularity": self.batch_unit or self.dg.granularity,
        }
        return Batch(raw, meta)

    def _run_hooks(self, batch: Batch) -> Batch:
        if self.manager is None:
            return batch
        return self.manager.execute(batch)


@partial(jax.jit, static_argnames=("num_rows", "capacity"))
def _tensorize_snapshots(usrc, udst, uct, count, *, num_rows: int,
                         capacity: int):
    """Scatter tick-major discretized events into ``(T, capacity)`` grids.

    Inputs are the padded outputs of ``discretize_edges_padded`` with
    ``uct`` already shifted to **zero-based** row ticks (the caller
    subtracts the first tick on staging, so huge absolute ticks can never
    overflow the int32 arithmetic here; padding keeps a large sentinel
    beyond ``count``, so the array stays globally sorted and the per-row
    extents come from one ``searchsorted``). Events beyond a row's
    ``capacity`` are dropped by the scatter's out-of-bounds semantics;
    callers size ``capacity`` to the max row count to make that impossible
    by construction.
    """
    g = usrc.shape[0]
    idx = jnp.arange(g, dtype=jnp.int32)
    valid = idx < count
    starts = jnp.searchsorted(
        uct, jnp.arange(num_rows, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    row = jnp.clip(uct, 0, num_rows - 1)
    pos = idx - starts[row]
    ok = valid & (pos < capacity)
    flat = jnp.where(ok, row * capacity + pos, num_rows * capacity)
    grid = lambda fill, dtype: jnp.full(num_rows * capacity, fill, dtype)
    src_g = grid(0, jnp.int32).at[flat].set(usrc)
    dst_g = grid(0, jnp.int32).at[flat].set(udst)
    mask_g = grid(False, bool).at[flat].set(ok)
    bounds = jnp.concatenate([starts, count[None].astype(jnp.int32)])
    counts = jnp.clip(jnp.diff(bounds), 0, capacity)
    shape = (num_rows, capacity)
    return (src_g.reshape(shape), dst_g.reshape(shape),
            mask_g.reshape(shape), counts)


def snapshot_tensor(
    data: DGData,
    granularity: TimeDelta | str,
    capacity: Optional[int] = None,
    device=None,
) -> SnapshotTensor:
    """Tensorize a stream into the device-resident ``SnapshotTensor`` view.

    One jitted ``discretize_edges_padded`` call collapses duplicate
    ``(tick, src, dst)`` classes at the target granularity, then one jitted
    scatter (``_tensorize_snapshots``) lays them out as padded
    ``(T, capacity)`` src/dst/mask device arrays. The only host syncs are
    build-time bookkeeping (valid count + per-row extents to choose the
    capacity); after this, a DTDG epoch touches no host arrays at all.

    ``capacity`` defaults to the max per-snapshot edge count rounded up to
    a power of two (one XLA compilation across granularities that land in
    the same bucket); passing a smaller value deterministically drops each
    oversized snapshot's tail.
    """
    from repro.core.discretize import (
        _coarse_ticks,
        _host_ticks,
        discretize_edges_padded,
        jax_discretize_supported,
    )

    unit = TimeDelta.coerce(granularity)
    k = _coarse_ticks(data, unit)
    e = data.num_edge_events
    span = data.time_span
    t0, t_end = span[0] // k, span[1] // k
    num_rows = max(int(t_end - t0) + 1, 1)

    if e and jax_discretize_supported(data, k, edges_only=True):
        t_staged, k_dev = _host_ticks(data.edge_t, k)
        usrc, udst, uct, _, count = discretize_edges_padded(
            jnp.asarray(data.src), jnp.asarray(data.dst),
            jnp.asarray(t_staged), jnp.zeros((e, 0), jnp.float32),
            k=k_dev, reduce="first", capacity=e, feat_dim=0,
        )
        # Zero-base the row ticks for the scatter (t0 >= 0, so the padded
        # int32-max sentinel shifts without wrapping and stays largest).
        uct = uct - np.int32(t0)
    else:  # int32 guard tripped (or empty stream): host numpy fallback
        disc = data.discretize(unit, reduce="first", backend="numpy")
        usrc = jnp.asarray(disc.src, jnp.int32)
        udst = jnp.asarray(disc.dst, jnp.int32)
        # Shift in int64 on host: absolute ticks can exceed int32 (that is
        # exactly why this branch runs), relative ones cannot.
        uct = jnp.asarray(disc.edge_t - t0, jnp.int32)
        count = jnp.asarray(disc.num_edge_events, jnp.int32)

    g = int(count)
    row_counts = np.bincount(
        np.asarray(uct[:g], dtype=np.int64), minlength=num_rows
    )
    if capacity is None:
        capacity = int(2 ** np.ceil(np.log2(max(row_counts.max(), 1))))
    src_g, dst_g, mask_g, counts = _tensorize_snapshots(
        usrc, udst, uct, count, num_rows=num_rows, capacity=int(capacity),
    )
    if device is not None:
        src_g, dst_g, mask_g, counts = jax.device_put(
            (src_g, dst_g, mask_g, counts), device)
    return SnapshotTensor(
        src=src_g, dst=dst_g, mask=mask_g, counts=counts,
        t0=int(t0), ticks=int(k), unit=unit, num_nodes=int(data.num_nodes),
    )


class _HostStagingPool:
    """Rotating reusable host staging buffers for ``PrefetchLoader``.

    Fresh numpy arrays from the hook pipeline live in pageable memory, so
    on GPU backends every ``jax.device_put`` pays a pageable->pinned copy
    inside the driver before the H2D DMA can overlap compute. Staging each
    batch into a small set of *reused* host buffers (one per batch key,
    rotated round-robin across ``depth`` slots) keeps the source addresses
    stable — the runtime's transfer machinery can keep them registered —
    and lets the transfer be issued with ``donate=True`` (the staged array
    is never read again by the producer).

    ``depth`` bounds how soon a slot can be rewritten (only after ``depth``
    newer batches were staged), and rewriting additionally blocks on the
    device array last transferred from that slot (``note`` /
    ``block_until_ready`` — normally a no-op that far behind the queue's
    back-pressure, but it makes reuse-before-DMA-completion impossible by
    construction rather than by timing). Rotation is explicit (``advance``
    once per batch) so every array of one batch shares a slot generation.
    """

    def __init__(self, depth: int):
        if depth < 2:
            raise ValueError("staging depth must be >= 2")
        self.depth = depth
        self._slot = 0
        self._bufs = {}
        self._pending = {}
        # XLA's CPU client zero-copies 64-byte-aligned host buffers into
        # device arrays, which would alias a reused slot straight into an
        # already-yielded batch. Deliberately misaligned slots force a real
        # copy there; on accelerators device memory is separate, so
        # alignment is kept for the H2D DMA's sake.
        import jax

        self._misalign = jax.default_backend() == "cpu"

    def _alloc(self, shape, dtype: np.dtype) -> np.ndarray:
        n = int(np.prod(shape))
        if not self._misalign:
            return np.empty(shape, dtype)
        extra = max(64 // max(dtype.itemsize, 1), 1)
        raw = np.empty(n + extra, dtype)
        for k in range(extra):
            if (raw.ctypes.data + k * dtype.itemsize) % 64:
                return raw[k:k + n].reshape(shape)
        return raw[:n].reshape(shape)  # unreachable: a window this wide
        # always contains a misaligned element address

    def advance(self) -> None:
        """Rotate to the next slot generation (call once per batch)."""
        self._slot = (self._slot + 1) % self.depth

    def stage(self, key: str, arr: np.ndarray) -> np.ndarray:
        """Copy ``arr`` into this slot's reusable buffer for ``key``
        (int64 narrowed to int32, matching ``DeviceTransferHook``),
        waiting out any still-pending transfer from the same slot first."""
        dtype = np.dtype(np.int32) if arr.dtype == np.int64 else arr.dtype
        k = (key, self._slot)
        pending = self._pending.pop(k, None)
        if pending is not None:
            pending.block_until_ready()
        buf = self._bufs.get(k)
        if buf is None or buf.shape != arr.shape or buf.dtype != dtype:
            buf = self._alloc(arr.shape, dtype)
            self._bufs[k] = buf
        np.copyto(buf, arr, casting="unsafe")
        return buf

    def note(self, key: str, device_array) -> None:
        """Record the device array transferred from this slot's ``key``
        buffer, so the slot's next rewrite can block on its completion."""
        self._pending[(key, self._slot)] = device_array


class PrefetchLoader:
    """Double-buffered device-staging wrapper around any batch iterable.

    While the consumer (the jitted train/eval step) is busy with batch ``i``,
    a daemon thread prepares batch ``i+1``: it pulls from ``inner`` (which
    runs the hook pipeline) and eagerly ships every numpy array to ``device``
    via ``jax.device_put`` (int64 narrowed to int32, matching
    ``DeviceTransferHook``). Arrays already on device pass through untouched,
    so it composes with device-resident hooks.

    ``device`` may also be a ``jax.sharding.Sharding`` — the mesh-sharded
    sampling pipeline passes the mesh-replicated ``NamedSharding`` here so
    prefetched batches land on the same device set as the ``shard_map``
    sampler state and the replicated model step (see ``docs/sharding.md``).

    ``telemetry`` (a ``repro.obs.Telemetry``; disabled default) makes the
    queue dynamics observable (``docs/observability.md``): a
    ``loader/stage`` span around each producer-side hook+staging pass, a
    ``loader/prefetch_wait`` histogram of how long the consumer blocked
    per batch, ``loader/producer_stall`` / ``loader/consumer_stall``
    counters (bounded-queue full on put / empty on get), a
    ``loader/queue_depth`` gauge sampled at each dequeue, and a
    ``loader/batches`` counter.

    ``staging`` enables the reusable host staging buffers
    (``_HostStagingPool``) so the H2D transfer reads from stable,
    re-registered addresses and can donate them; ``None`` (default)
    auto-enables this on GPU backends only — on CPU "transfer" is a local
    copy and staging would only add another one. Donation is never applied
    on CPU, where ``jax.device_put(..., donate=True)`` zero-copy *aliases*
    the source buffer and a reused slot would corrupt earlier batches.

    Exceptions raised in the producer are re-raised in the consumer **with
    the original traceback** (the exception instance travels through the
    queue, FIFO with the batches staged before it, so already-prepared
    batches are still delivered first and the error surfaces within one
    ``next()``). If the producer thread dies without delivering either the
    end-of-stream sentinel or an exception, the consumer raises
    ``RuntimeError`` instead of blocking forever. The producer thread exits
    promptly when the consumer stops iterating (``close``, or abandoning
    the iterator) because the bounded queue blocks with a timeout and
    checks a stop flag.
    """

    _END = object()

    def __init__(self, inner, device=None, prefetch: int = 2,
                 staging: Optional[bool] = None, telemetry=None):
        if prefetch < 1:
            raise ValueError("prefetch depth must be >= 1")
        from repro.obs import NULL

        self.inner = inner
        self._device = device
        self.prefetch = prefetch
        self.telemetry = telemetry if telemetry is not None else NULL
        self._active: list = []  # live (stop, thread) pairs, for close()
        self._active_lock = threading.Lock()
        if staging is None:
            staging = jax.default_backend() == "gpu"
        self.staging = staging
        # depth > max batches in flight: `prefetch` queued + 1 being
        # consumed + 1 being produced.
        self._pool = _HostStagingPool(prefetch + 2) if staging else None

    def __len__(self) -> int:
        return len(self.inner)

    def _stage(self, batch: Batch) -> Batch:
        from repro.core.tg_hooks import stage_batch

        if self._pool is not None:
            self._pool.advance()
        return stage_batch(batch, self._device, pool=self._pool)

    def __iter__(self) -> Iterator[Batch]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()
        tel = self.telemetry

        def put_or_stop(item) -> bool:
            """Bounded put that aborts when the consumer has left."""
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    # Back-pressure: the consumer is the bottleneck here.
                    tel.count("loader/producer_stall")
                    continue
            return False

        def produce():
            try:
                for batch in self.inner:
                    with tel.span("loader/stage"):
                        staged = self._stage(batch)
                    if not put_or_stop(staged):
                        return
                put_or_stop(self._END)
            except BaseException as e:  # surfaced on the consumer side
                put_or_stop(e)

        thread = threading.Thread(target=produce, daemon=True)
        with self._active_lock:
            self._active.append((stop, thread))
        thread.start()
        try:
            while True:
                wait_t0 = time.perf_counter()
                try:
                    item = q.get(timeout=0.2)
                except queue.Empty:
                    if stop.is_set():  # close() mid-iteration: clean end
                        return
                    if not thread.is_alive():
                        raise RuntimeError(
                            "PrefetchLoader producer thread died without "
                            "signalling end-of-stream or an error")
                    # Starvation: the producer is the bottleneck here.
                    tel.count("loader/consumer_stall")
                    continue
                if tel.enabled:
                    tel.observe("loader/prefetch_wait",
                                time.perf_counter() - wait_t0)
                    tel.gauge("loader/queue_depth", q.qsize())
                if item is self._END:
                    return
                if isinstance(item, BaseException):
                    # Re-raising the instance keeps the producer-side
                    # traceback (it rode along on __traceback__).
                    raise item
                tel.count("loader/batches")
                yield item
        finally:
            stop.set()
            with self._active_lock:
                self._active = [a for a in self._active if a[0] is not stop]

    def close(self) -> None:
        """Stop all producer threads spawned by active iterations and join
        them. Idempotent: safe to call repeatedly or with no iteration in
        flight; consumers still blocked in ``next()`` observe a clean end
        of iteration."""
        with self._active_lock:
            active = list(self._active)
        for stop, thread in active:
            stop.set()
        for stop, thread in active:
            thread.join(timeout=5)
