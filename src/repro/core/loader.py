"""Unified CTDG/DTDG data loading (paper Defs. 3.3-3.4, Fig. 2).

``DGDataLoader`` iterates a ``DGraph`` view either

  * **by events** (CTDG): fixed event-count batches under the event-ordered
    granularity, or
  * **by time** (DTDG): fixed wall-clock windows of the view's (coarser)
    granularity — batches are snapshots ``G|_[t_i, t_i + tau_hat)``; empty
    windows can be emitted or skipped.

Each batch is materialized from storage, passed through the ``HookManager``
pipeline, and returned as a ``Batch``.
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.core.batch import Batch
from repro.core.graph import DGraph
from repro.core.granularity import TimeDelta
from repro.core.hooks import HookManager


class DGDataLoader:
    def __init__(
        self,
        dg: DGraph,
        hook_manager: Optional[HookManager] = None,
        batch_size: Optional[int] = 200,
        batch_unit: Optional[TimeDelta | str] = None,
        drop_last: bool = False,
        emit_empty: bool = False,
        window_ticks: int = 1,
    ):
        """Iterate ``dg``.

        Exactly one of ``batch_size`` (iterate-by-events) or ``batch_unit``
        (iterate-by-time) must be set. ``window_ticks`` scales the time
        window (e.g. unit='h', window_ticks=6 -> 6-hour snapshots).
        """
        if (batch_size is None) == (batch_unit is None):
            raise ValueError("set exactly one of batch_size / batch_unit")
        self.dg = dg
        self.manager = hook_manager
        self.batch_size = batch_size
        self.drop_last = drop_last
        self.emit_empty = emit_empty
        self.window_ticks = window_ticks
        if batch_unit is not None:
            unit = TimeDelta.coerce(batch_unit)
            native = dg.data.granularity
            if native.is_event_ordered:
                raise ValueError(
                    "iterate-by-time requires a real-time native granularity; "
                    "this graph is event-ordered (paper §3)"
                )
            if not unit.is_coarser_or_equal(native):
                raise ValueError(f"batch unit {unit} must be >= native {native}")
            self.batch_unit = unit
            self._ticks = unit.ticks_per(native) * window_ticks
        else:
            self.batch_unit = None
            self._ticks = None

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        if self.batch_size is not None:
            n = self.dg.num_edge_events
            full, rem = divmod(n, self.batch_size)
            return full if (self.drop_last or rem == 0) else full + 1
        span = self.dg.t_hi - self.dg.t_lo
        return int(np.ceil(span / self._ticks))

    def __iter__(self) -> Iterator[Batch]:
        if self.batch_size is not None:
            yield from self._iter_events()
        else:
            yield from self._iter_time()

    # -- CTDG: fixed event count ----------------------------------------
    def _iter_events(self) -> Iterator[Batch]:
        lo, hi = self.dg.edge_slice()
        for start in range(lo, hi, self.batch_size):
            stop = min(start + self.batch_size, hi)
            if self.drop_last and stop - start < self.batch_size:
                break
            batch = self._materialize(start, stop)
            yield self._run_hooks(batch)

    # -- DTDG: fixed time window ------------------------------------------
    def _iter_time(self) -> Iterator[Batch]:
        data = self.dg.data
        t = self.dg.t_lo
        while t < self.dg.t_hi:
            t_next = min(t + self._ticks, self.dg.t_hi)
            lo, hi = data.edge_range(t, t_next)
            if hi > lo or self.emit_empty:
                batch = self._materialize(lo, hi, window=(t, t_next))
                yield self._run_hooks(batch)
            t = t_next

    # ------------------------------------------------------------------
    def _materialize(self, lo: int, hi: int, window=None) -> Batch:
        raw = self.dg.materialize(lo, hi)
        meta = {
            "eids": np.arange(lo, hi, dtype=np.int64),
            "window": window,
            "granularity": self.batch_unit or self.dg.granularity,
        }
        return Batch(raw, meta)

    def _run_hooks(self, batch: Batch) -> Batch:
        if self.manager is None:
            return batch
        return self.manager.execute(batch)
