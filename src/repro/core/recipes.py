"""Pre-defined hook recipes (paper §4: "we provide pre-defined recipes for
common tasks such as TGB link prediction, helping new practitioners avoid
common pitfalls like mismanaging state across data splits or using incorrect
negatives").

A recipe is a named factory that builds a ``HookManager`` with the right
hooks under the right activation keys:

  RECIPE_TGB_LINK      : training negatives (random) + eval one-vs-many
                         negatives + recency neighbors (+dedup) + edge-feature
                         lookup + pad + device transfer. The sampling strategy
                         is declared by ``spec=repro.tg.SamplerSpec(...)``
                         (``device=True`` swaps the host numpy buffers for the
                         device-resident JAX sampler twins — same outputs;
                         neighbor tensors born on device); the pre-spec kwargs
                         still work with a DeprecationWarning.
  RECIPE_TGB_NODE      : recency neighbors + pad + device transfer (labels
                         come from the dataset).
  RECIPE_DTDG_SNAPSHOT : snapshot link-prediction pipeline — per-snapshot
                         train/eval negatives (counter-derived, bit-identical
                         to the scan-compiled path's bulk draws) + device
                         transfer. Models consume whole padded snapshots;
                         see ``docs/dtdg.md``.
  RECIPE_ANALYTICS_DOS : density-of-states analytics (paper Fig. 3).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from repro.core.hooks import HookManager
from repro.core.tg_hooks import (
    DeviceRecencyNeighborHook,
    DeviceTransferHook,
    DeviceUniformNeighborHook,
    DOSEstimateHook,
    EdgeFeatureLookupHook,
    NegativeEdgeHook,
    PadBatchHook,
    RecencyNeighborHook,
    SnapshotNegativeHook,
    TGBEvalNegativesHook,
    UniformNeighborHook,
)

RECIPE_TGB_LINK = "tgb_link"
RECIPE_TGB_NODE = "tgb_node"
RECIPE_DTDG_SNAPSHOT = "dtdg_snapshot"
RECIPE_ANALYTICS_DOS = "analytics_dos"

TRAIN_KEY = "train"
EVAL_KEY = "eval"


class RecipeRegistry:
    """Name -> HookManager-factory registry for pre-defined recipes."""

    _builders: Dict[str, Callable[..., HookManager]] = {}

    @classmethod
    def register(cls, name: str):
        """Decorator: register a recipe factory under ``name``."""
        def deco(fn):
            cls._builders[name] = fn
            return fn

        return deco

    @classmethod
    def build(cls, name: str, **kwargs) -> HookManager:
        """Instantiate the recipe ``name`` with factory kwargs."""
        if name not in cls._builders:
            raise KeyError(f"unknown recipe {name!r}; have {sorted(cls._builders)}")
        return cls._builders[name](**kwargs)

    @classmethod
    def available(cls):
        """Sorted names of all registered recipes."""
        return sorted(cls._builders)


# Sentinel distinguishing "legacy kwarg explicitly passed" from defaults.
_UNSET = object()


def _legacy_sampler_spec(k, num_hops, device_sampling, sampler, expose_buffer,
                         checkpoint_adjacency):
    """Map the pre-spec kwarg surface onto a ``SamplerSpec``, warning once
    per call when any legacy strategy kwarg was explicitly passed."""
    import warnings

    from repro.tg.specs import SamplerSpec

    legacy = {
        "device_sampling": device_sampling,
        "sampler": sampler,
        "expose_buffer": expose_buffer,
        "checkpoint_adjacency": checkpoint_adjacency,
    }
    passed = sorted(name for name, v in legacy.items() if v is not _UNSET)
    if passed:
        warnings.warn(
            f"RECIPE_TGB_LINK legacy kwargs {passed} are deprecated; pass "
            f"spec=repro.tg.SamplerSpec(...) instead (see docs/experiment.md)",
            DeprecationWarning,
            stacklevel=3,
        )
    return SamplerSpec(
        kind="recency" if sampler is _UNSET else sampler,
        k=k,
        num_hops=num_hops,
        device=False if device_sampling is _UNSET else bool(device_sampling),
        checkpoint_adjacency=(True if checkpoint_adjacency is _UNSET
                              else bool(checkpoint_adjacency)),
        expose_buffer=None if expose_buffer is _UNSET else expose_buffer,
    )


@RecipeRegistry.register(RECIPE_TGB_LINK)
def _tgb_link(
    num_nodes: int,
    k: Optional[int] = None,
    num_hops: Optional[int] = None,
    batch_size: int = 200,
    eval_negatives: int = 100,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    dst_pool: Optional[np.ndarray] = None,
    seed: int = 0,
    device=None,
    spec=None,
    mesh=None,
    mesh_axis: str = "data",
    device_sampling=_UNSET,
    sampler=_UNSET,
    expose_buffer=_UNSET,
    checkpoint_adjacency=_UNSET,
) -> HookManager:
    """Build the TGB link-prediction hook pipeline.

    The sampling strategy comes from ``spec`` — a
    ``repro.tg.SamplerSpec``: ``kind`` selects recency (K most recent,
    circular buffers) vs uniform (K uniform draws from the strict past;
    hop-1 or recursive hop-2 frontier, and the returned hook's
    ``build(...)`` must be called with the edge storage before iterating);
    ``device=True`` swaps in the device-resident twin of either sampler
    (same outputs / checkpoint contract; tensors born on device);
    ``expose_buffer`` forwards to ``DeviceRecencyNeighborHook`` (``None``
    = backend auto; ``False`` for models without a fused attention path so
    buffer updates can donate in place); ``checkpoint_adjacency=False``
    keeps the uniform samplers' O(E) CSR out of ``state_dict``
    (counter-only checkpoints; the adjacency is rebuilt from storage by
    the restoring pipeline's ``build``). With ``spec`` given, the
    sampling-strategy arguments — including ``k`` and ``num_hops`` — must
    come from the spec; passing both raises.

    The pre-spec kwargs (``k=``, ``num_hops=``, ``device_sampling=``,
    ``sampler=``, ``expose_buffer=``, ``checkpoint_adjacency=``) are still
    accepted without a spec; the strategy ones are deprecated and mapped
    onto a ``SamplerSpec`` with a ``DeprecationWarning``.

    ``mesh`` (or ``spec.shards``, which resolves to a mesh here) shards
    the device samplers' state row-wise by node id over a 1-D mesh and
    routes the device transfer through a mesh-replicated placement so
    batch tensors and sharded sampler state live on the same device set —
    see ``docs/sharding.md``. Requires ``spec.device=True``.
    """
    if spec is None:
        spec = _legacy_sampler_spec(
            20 if k is None else k, 1 if num_hops is None else num_hops,
            device_sampling, sampler, expose_buffer, checkpoint_adjacency,
        )
    elif (k is not None or num_hops is not None
          or any(v is not _UNSET for v in (device_sampling, sampler,
                                           expose_buffer,
                                           checkpoint_adjacency))):
        raise ValueError(
            "pass either spec=SamplerSpec(...) or the legacy sampler kwargs "
            "(k/num_hops/device_sampling/sampler/expose_buffer/"
            "checkpoint_adjacency), not both"
        )
    k = spec.k
    num_hops = spec.num_hops if spec.num_hops is not None else 1
    device_sampling = spec.device
    if mesh is None and getattr(spec, "shards", None):
        from repro.distributed.sharding import make_node_mesh

        # Spec-driven construction: the spec names the axis too, so a
        # JSON-round-tripped spec behaves identically here and through
        # CTDGLinkPipeline (an explicitly passed mesh keeps the kwarg).
        mesh_axis = spec.mesh_axis
        mesh = make_node_mesh(spec.shards, mesh_axis)
    if mesh is not None:
        if not device_sampling:
            raise ValueError(
                "mesh-sharded sampling requires SamplerSpec(device=True)"
            )
        if device is not None:
            raise ValueError(
                "pass either device= or a sampler mesh (mesh=/spec.shards), "
                "not both — with a mesh, batch tensors are placed "
                "mesh-replicated so they share the sharded state's device "
                "set (docs/sharding.md)"
            )
        from repro.distributed.sharding import replicated_sharding

        # Batch tensors must land replicated on the mesh's device set so
        # the sharded sampler jits and the model step see one placement.
        device = replicated_sharding(mesh)
    m = HookManager()
    # Padding runs FIRST so negatives/neighbor tensors come out fixed-shape;
    # stateful hooks exclude padded events via batch_mask.
    m.register(PadBatchHook(batch_size))
    m.register(
        NegativeEdgeHook(num_nodes, num_negatives=1, seed=seed, dst_pool=dst_pool),
        key=TRAIN_KEY,
    )
    m.register(
        TGBEvalNegativesHook(num_nodes, num_negatives=eval_negatives, seed=seed,
                             dst_pool=dst_pool),
        key=EVAL_KEY,
    )
    # One shared neighbor sampler serves both train and eval keys (state is
    # shared; recency buffer updates exclude padding and happen once per
    # batch). ``spec.device`` swaps the host numpy implementation for the
    # JAX device-resident twin (same outputs, no host round-trip).
    if spec.kind == "uniform":
        if device_sampling:
            m.register(DeviceUniformNeighborHook(
                num_nodes, k, include_negatives=True, seed=seed,
                device=None if mesh is not None else device,
                num_hops=num_hops,
                checkpoint_adjacency=spec.checkpoint_adjacency,
                mesh=mesh, mesh_axis=mesh_axis,
                partition=getattr(spec, "partition", "rows")))
        else:
            m.register(UniformNeighborHook(
                num_nodes, k, include_negatives=True, seed=seed,
                num_hops=num_hops,
                checkpoint_adjacency=spec.checkpoint_adjacency))
    elif device_sampling:
        m.register(DeviceRecencyNeighborHook(num_nodes, k, num_hops=num_hops,
                                             device=None if mesh is not None
                                             else device,
                                             expose_buffer=spec.expose_buffer,
                                             edge_feats=edge_feats,
                                             mesh=mesh, mesh_axis=mesh_axis))
    else:
        m.register(RecencyNeighborHook(num_nodes, k, num_hops=num_hops, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    if num_hops == 2:
        m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim, prefix="nbr2"))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_TGB_NODE)
def _tgb_node(
    num_nodes: int,
    k: int = 20,
    batch_size: int = 200,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    device=None,
) -> HookManager:
    m = HookManager()
    m.register(PadBatchHook(batch_size))
    m.register(RecencyNeighborHook(num_nodes, k, include_negatives=False, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_DTDG_SNAPSHOT)
def _dtdg_snapshot(
    num_nodes: Optional[int] = None,
    capacity: Optional[int] = None,
    num_negatives: int = 1,
    eval_negatives: int = 20,
    seed: int = 0,
    device=None,
) -> HookManager:
    """Build the DTDG snapshot link-prediction hook pipeline.

    With ``num_nodes``/``capacity`` given, registers counter-derived
    per-snapshot negative hooks under the train/eval activation keys
    (``SnapshotNegativeHook``; the draws are a pure function of the
    snapshot row, so the hook path matches the scan-compiled epoch's bulk
    draws bit-for-bit). Without them (legacy callers), the recipe degrades
    to the plain device-transfer pipeline.
    """
    m = HookManager()
    if num_nodes is not None and capacity is not None:
        m.register(
            SnapshotNegativeHook(num_nodes, capacity, num_negatives, seed=seed),
            key=TRAIN_KEY,
        )
        m.register(
            SnapshotNegativeHook(num_nodes, capacity, eval_negatives, seed=seed),
            key=EVAL_KEY,
        )
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_ANALYTICS_DOS)
def _analytics_dos(num_nodes: int, num_moments: int = 10, seed: int = 0) -> HookManager:
    m = HookManager()
    m.register(DOSEstimateHook(num_nodes, num_moments=num_moments, seed=seed))
    return m
