"""Pre-defined hook recipes (paper §4: "we provide pre-defined recipes for
common tasks such as TGB link prediction, helping new practitioners avoid
common pitfalls like mismanaging state across data splits or using incorrect
negatives").

A recipe is a named factory that builds a ``HookManager`` with the right
hooks under the right activation keys:

  RECIPE_TGB_LINK      : training negatives (random) + eval one-vs-many
                         negatives + recency neighbors (+dedup) + edge-feature
                         lookup + pad + device transfer. Pass
                         ``device_sampling=True`` to swap the host numpy
                         recency buffers for the device-resident JAX sampler
                         (same outputs; neighbor tensors born on device).
  RECIPE_TGB_NODE      : recency neighbors + pad + device transfer (labels
                         come from the dataset).
  RECIPE_DTDG_SNAPSHOT : snapshot pipeline (no sampling; models consume whole
                         snapshots) + device transfer.
  RECIPE_ANALYTICS_DOS : density-of-states analytics (paper Fig. 3).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from repro.core.hooks import HookManager
from repro.core.tg_hooks import (
    DeviceRecencyNeighborHook,
    DeviceTransferHook,
    DOSEstimateHook,
    EdgeFeatureLookupHook,
    NegativeEdgeHook,
    PadBatchHook,
    RecencyNeighborHook,
    TGBEvalNegativesHook,
)

RECIPE_TGB_LINK = "tgb_link"
RECIPE_TGB_NODE = "tgb_node"
RECIPE_DTDG_SNAPSHOT = "dtdg_snapshot"
RECIPE_ANALYTICS_DOS = "analytics_dos"

TRAIN_KEY = "train"
EVAL_KEY = "eval"


class RecipeRegistry:
    _builders: Dict[str, Callable[..., HookManager]] = {}

    @classmethod
    def register(cls, name: str):
        def deco(fn):
            cls._builders[name] = fn
            return fn

        return deco

    @classmethod
    def build(cls, name: str, **kwargs) -> HookManager:
        if name not in cls._builders:
            raise KeyError(f"unknown recipe {name!r}; have {sorted(cls._builders)}")
        return cls._builders[name](**kwargs)

    @classmethod
    def available(cls):
        return sorted(cls._builders)


@RecipeRegistry.register(RECIPE_TGB_LINK)
def _tgb_link(
    num_nodes: int,
    k: int = 20,
    num_hops: int = 1,
    batch_size: int = 200,
    eval_negatives: int = 100,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    dst_pool: Optional[np.ndarray] = None,
    seed: int = 0,
    device=None,
    device_sampling: bool = False,
) -> HookManager:
    m = HookManager()
    # Padding runs FIRST so negatives/neighbor tensors come out fixed-shape;
    # stateful hooks exclude padded events via batch_mask.
    m.register(PadBatchHook(batch_size))
    m.register(
        NegativeEdgeHook(num_nodes, num_negatives=1, seed=seed, dst_pool=dst_pool),
        key=TRAIN_KEY,
    )
    m.register(
        TGBEvalNegativesHook(num_nodes, num_negatives=eval_negatives, seed=seed,
                             dst_pool=dst_pool),
        key=EVAL_KEY,
    )
    # One shared recency sampler serves both train and eval keys (state is
    # shared; buffer updates exclude padding and happen once per batch).
    # ``device_sampling`` swaps the host numpy circular buffers for the
    # JAX device-resident sampler (same outputs, no host round-trip).
    if device_sampling:
        m.register(DeviceRecencyNeighborHook(num_nodes, k, num_hops=num_hops,
                                             device=device))
    else:
        m.register(RecencyNeighborHook(num_nodes, k, num_hops=num_hops, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    if num_hops == 2:
        m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim, prefix="nbr2"))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_TGB_NODE)
def _tgb_node(
    num_nodes: int,
    k: int = 20,
    batch_size: int = 200,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    device=None,
) -> HookManager:
    m = HookManager()
    m.register(PadBatchHook(batch_size))
    m.register(RecencyNeighborHook(num_nodes, k, include_negatives=False, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_DTDG_SNAPSHOT)
def _dtdg_snapshot(device=None) -> HookManager:
    m = HookManager()
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_ANALYTICS_DOS)
def _analytics_dos(num_nodes: int, num_moments: int = 10, seed: int = 0) -> HookManager:
    m = HookManager()
    m.register(DOSEstimateHook(num_nodes, num_moments=num_moments, seed=seed))
    return m
