"""Pre-defined hook recipes (paper §4: "we provide pre-defined recipes for
common tasks such as TGB link prediction, helping new practitioners avoid
common pitfalls like mismanaging state across data splits or using incorrect
negatives").

A recipe is a named factory that builds a ``HookManager`` with the right
hooks under the right activation keys:

  RECIPE_TGB_LINK      : training negatives (random) + eval one-vs-many
                         negatives + recency neighbors (+dedup) + edge-feature
                         lookup + pad + device transfer. Pass
                         ``device_sampling=True`` to swap the host numpy
                         recency buffers for the device-resident JAX sampler
                         (same outputs; neighbor tensors born on device).
  RECIPE_TGB_NODE      : recency neighbors + pad + device transfer (labels
                         come from the dataset).
  RECIPE_DTDG_SNAPSHOT : snapshot link-prediction pipeline — per-snapshot
                         train/eval negatives (counter-derived, bit-identical
                         to the scan-compiled path's bulk draws) + device
                         transfer. Models consume whole padded snapshots;
                         see ``docs/dtdg.md``.
  RECIPE_ANALYTICS_DOS : density-of-states analytics (paper Fig. 3).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from repro.core.hooks import HookManager
from repro.core.tg_hooks import (
    DeviceRecencyNeighborHook,
    DeviceTransferHook,
    DeviceUniformNeighborHook,
    DOSEstimateHook,
    EdgeFeatureLookupHook,
    NegativeEdgeHook,
    PadBatchHook,
    RecencyNeighborHook,
    SnapshotNegativeHook,
    TGBEvalNegativesHook,
    UniformNeighborHook,
)

RECIPE_TGB_LINK = "tgb_link"
RECIPE_TGB_NODE = "tgb_node"
RECIPE_DTDG_SNAPSHOT = "dtdg_snapshot"
RECIPE_ANALYTICS_DOS = "analytics_dos"

TRAIN_KEY = "train"
EVAL_KEY = "eval"


class RecipeRegistry:
    """Name -> HookManager-factory registry for pre-defined recipes."""

    _builders: Dict[str, Callable[..., HookManager]] = {}

    @classmethod
    def register(cls, name: str):
        """Decorator: register a recipe factory under ``name``."""
        def deco(fn):
            cls._builders[name] = fn
            return fn

        return deco

    @classmethod
    def build(cls, name: str, **kwargs) -> HookManager:
        """Instantiate the recipe ``name`` with factory kwargs."""
        if name not in cls._builders:
            raise KeyError(f"unknown recipe {name!r}; have {sorted(cls._builders)}")
        return cls._builders[name](**kwargs)

    @classmethod
    def available(cls):
        """Sorted names of all registered recipes."""
        return sorted(cls._builders)


@RecipeRegistry.register(RECIPE_TGB_LINK)
def _tgb_link(
    num_nodes: int,
    k: int = 20,
    num_hops: int = 1,
    batch_size: int = 200,
    eval_negatives: int = 100,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    dst_pool: Optional[np.ndarray] = None,
    seed: int = 0,
    device=None,
    device_sampling: bool = False,
    sampler: str = "recency",
    expose_buffer: Optional[bool] = None,
    checkpoint_adjacency: bool = True,
) -> HookManager:
    """Build the TGB link-prediction hook pipeline.

    ``sampler`` selects the temporal neighbor strategy: ``"recency"`` (K
    most recent, circular buffers) or ``"uniform"`` (K uniform draws from
    the strict past; hop-1 or recursive hop-2 frontier, and the returned
    hook's ``build(...)`` must be called with the edge storage before
    iterating). ``device_sampling=True`` swaps in the device-resident twin
    of either sampler (same outputs / checkpoint contract; tensors born on
    device). ``expose_buffer`` forwards to ``DeviceRecencyNeighborHook``
    (None = backend auto; pass False for models without a fused attention
    path so buffer updates can donate in place). ``checkpoint_adjacency``
    forwards to the uniform samplers: ``False`` drops the O(E) CSR from
    ``state_dict`` (counter-only checkpoints; the adjacency is rebuilt from
    storage by the restoring trainer's ``build``).
    """
    if sampler not in ("recency", "uniform"):
        raise ValueError(f"unknown sampler {sampler!r}; use 'recency' or 'uniform'")
    m = HookManager()
    # Padding runs FIRST so negatives/neighbor tensors come out fixed-shape;
    # stateful hooks exclude padded events via batch_mask.
    m.register(PadBatchHook(batch_size))
    m.register(
        NegativeEdgeHook(num_nodes, num_negatives=1, seed=seed, dst_pool=dst_pool),
        key=TRAIN_KEY,
    )
    m.register(
        TGBEvalNegativesHook(num_nodes, num_negatives=eval_negatives, seed=seed,
                             dst_pool=dst_pool),
        key=EVAL_KEY,
    )
    # One shared neighbor sampler serves both train and eval keys (state is
    # shared; recency buffer updates exclude padding and happen once per
    # batch). ``device_sampling`` swaps the host numpy implementation for
    # the JAX device-resident twin (same outputs, no host round-trip).
    if sampler == "uniform":
        if device_sampling:
            m.register(DeviceUniformNeighborHook(
                num_nodes, k, include_negatives=True, seed=seed, device=device,
                num_hops=num_hops, checkpoint_adjacency=checkpoint_adjacency))
        else:
            m.register(UniformNeighborHook(
                num_nodes, k, include_negatives=True, seed=seed,
                num_hops=num_hops, checkpoint_adjacency=checkpoint_adjacency))
    elif device_sampling:
        m.register(DeviceRecencyNeighborHook(num_nodes, k, num_hops=num_hops,
                                             device=device,
                                             expose_buffer=expose_buffer,
                                             edge_feats=edge_feats))
    else:
        m.register(RecencyNeighborHook(num_nodes, k, num_hops=num_hops, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    if num_hops == 2:
        m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim, prefix="nbr2"))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_TGB_NODE)
def _tgb_node(
    num_nodes: int,
    k: int = 20,
    batch_size: int = 200,
    edge_feats: Optional[np.ndarray] = None,
    edge_feat_dim: int = 0,
    device=None,
) -> HookManager:
    m = HookManager()
    m.register(PadBatchHook(batch_size))
    m.register(RecencyNeighborHook(num_nodes, k, include_negatives=False, dedup=True))
    m.register(EdgeFeatureLookupHook(edge_feats, edge_feat_dim))
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_DTDG_SNAPSHOT)
def _dtdg_snapshot(
    num_nodes: Optional[int] = None,
    capacity: Optional[int] = None,
    num_negatives: int = 1,
    eval_negatives: int = 20,
    seed: int = 0,
    device=None,
) -> HookManager:
    """Build the DTDG snapshot link-prediction hook pipeline.

    With ``num_nodes``/``capacity`` given, registers counter-derived
    per-snapshot negative hooks under the train/eval activation keys
    (``SnapshotNegativeHook``; the draws are a pure function of the
    snapshot row, so the hook path matches the scan-compiled epoch's bulk
    draws bit-for-bit). Without them (legacy callers), the recipe degrades
    to the plain device-transfer pipeline.
    """
    m = HookManager()
    if num_nodes is not None and capacity is not None:
        m.register(
            SnapshotNegativeHook(num_nodes, capacity, num_negatives, seed=seed),
            key=TRAIN_KEY,
        )
        m.register(
            SnapshotNegativeHook(num_nodes, capacity, eval_negatives, seed=seed),
            key=EVAL_KEY,
        )
    m.register(DeviceTransferHook(device))
    return m


@RecipeRegistry.register(RECIPE_ANALYTICS_DOS)
def _analytics_dos(num_nodes: int, num_moments: int = 10, seed: int = 0) -> HookManager:
    m = HookManager()
    m.register(DOSEstimateHook(num_nodes, num_moments=num_moments, seed=seed))
    return m
