"""Device-resident recency sampling (the `device_sampling=True` pipeline).

``DeviceRecencySampler`` is the JAX twin of ``RecencySampler``: the per-node
circular buffers (``ids/times/eids`` plus ``cursor``/``count``) live on the
accelerator as a pytree of ``int32`` arrays, and both ``update`` and
``sample`` are jit-compiled pure functions over that pytree. On non-CPU
backends the state argument is donated, so the buffers are updated in place
— no host round-trip and no reallocation per batch.

State layout (chosen from scatter microbenchmarks — XLA scatter cost is per
index row, so the three value channels share one scatter):

  ``buf``: (N+1, K, 3) int32 — channels = (neighbor id, time, edge id)
  ``cc``:  (N+1, 2)    int32 — columns  = (cursor, count)

Row ``N`` is a write sink for dropped/padded events and is never read.
``state_dict`` still speaks the canonical ``ids/times/eids/cursor/count``
contract shared with the host sampler, so checkpoints are interchangeable.

Slot assignment replaces the host-numpy argsort trick with an on-device
segment-cumsum scheme (fixed shapes, one XLA compilation per batch shape):

  1. sort a single fused integer key ``node * m + stream_pos`` — this both
     groups by node and keeps each node's events in stream (= time) order;
  2. per-element sequence number ``seq`` within its node group via a running
     max over group-start positions (cummax = segment cumsum of ones), and
     group multiplicity via a reverse running min over group ends — no
     second scatter;
  3. only the *last K* events of each node survive (sequential semantics
     under wraparound) and every survivor maps to a distinct
     ``(node, (cursor + seq) % K)`` cell, so the packed scatter has no
     meaningful duplicate targets (collisions are confined to the sink row)
     and is bit-deterministic.

Outputs are bit-identical to ``SequentialRecencySampler`` (see
``tests/test_sampler.py`` property tests), including cursor wraparound when
one batch carries more than K events for a node, and duplicate timestamps.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampler import NeighborBlock

_SCATTER_KW = dict(unique_indices=True, mode="promise_in_bounds")


def as_int32(a, name: str):
    """Narrow host arrays to int32, loudly rejecting values that would wrap
    (device sampler state is int32; silent truncation would corrupt parity
    with the int64 host samplers). Device arrays pass through untouched —
    no synchronization on hot paths. Shared by both device samplers."""
    if not isinstance(a, jax.Array):
        a = np.asarray(a)
        if a.dtype.itemsize > 4 and a.size and (
                a.max() >= 2**31 or a.min() < -(2**31)):
            raise ValueError(
                f"{name} exceeds int32 range; rescale (e.g. coarser time "
                f"granularity / epoch-relative timestamps) before "
                f"device sampling"
            )
    return jnp.asarray(a, jnp.int32)


def _update_impl(state, src, dst, t, eids, valid, *, k: int, directed: bool):
    """Insert a time-ordered batch into the circular buffers. Pure/jit."""
    sink = state["cc"].shape[0] - 1  # row N: write target for dropped events

    if directed:
        nodes, ok = src, valid
        vals = jnp.stack([dst, t, eids], axis=-1)  # (m, 3)
    else:
        # Interleave src/dst copies (event i -> stream positions 2i, 2i+1) so
        # the flattened stream preserves exact sequential insertion order.
        nodes = jnp.stack([src, dst], 1).reshape(-1)
        ok = jnp.stack([valid, valid], 1).reshape(-1)
        vals = jnp.stack([
            jnp.stack([dst, src], 1).reshape(-1),
            jnp.stack([t, t], 1).reshape(-1),
            jnp.stack([eids, eids], 1).reshape(-1),
        ], axis=-1)

    m = nodes.shape[0]
    nodes = jnp.where(ok, nodes, sink)
    idx = jnp.arange(m, dtype=jnp.int32)

    # One fused sort key: groups by node, stream order within the group.
    if (sink + 1) * m < 2**31:
        key = nodes * m + idx
        skey = jax.lax.sort(key)
        sn = skey // m
        pos = skey % m
    else:
        # Huge graphs: the fused int32 key would overflow (and int64 is
        # unavailable without jax_enable_x64), so use a stable two-operand
        # sort keyed on the node id with the stream position carried along.
        sn, pos = jax.lax.sort((nodes, idx), is_stable=True, num_keys=1)

    group_start = jnp.concatenate([jnp.ones(1, bool), sn[1:] != sn[:-1]])
    group_end = jnp.concatenate([sn[1:] != sn[:-1], jnp.ones(1, bool)])
    # Segment cumsum of ones: seq[i] = i - (position of i's group head);
    # multiplicity = (position past my group's tail) - head. Both via scans.
    head = jax.lax.associative_scan(
        jnp.maximum, jnp.where(group_start, idx, -1)
    )
    seq = idx - head
    tail = jax.lax.associative_scan(
        jnp.minimum, jnp.where(group_end, idx + 1, m), reverse=True
    )
    mult = tail - head

    # Sequential semantics under wraparound: only the last K events per node
    # are visible afterwards. Earlier ones go to the sink row, where slot
    # collisions are harmless (never read); surviving targets are unique ->
    # the scatter is bit-deterministic.
    survives = (seq >= mult - k) & (sn != sink)
    tgt = jnp.where(survives, sn, sink)
    cur = state["cc"][sn, 0]
    slots = jnp.where(survives, (cur + seq) % k, idx % k)
    buf = state["buf"].at[tgt, slots].set(vals[pos], **_SCATTER_KW)

    # Cursor/count advance by per-node multiplicity; one write per group
    # (group heads), the rest land in the sink row.
    chead = group_start & (sn != sink)
    ctgt = jnp.where(chead, sn, sink)
    ccv = jnp.stack([
        (cur + mult) % k,
        jnp.minimum(state["cc"][sn, 1] + mult, k),
    ], axis=-1)
    cc = state["cc"].at[ctgt].set(ccv, **_SCATTER_KW)
    return {"buf": buf, "cc": cc}


@partial(jax.jit, static_argnames=("k", "directed"), donate_argnums=(0,))
def _update_donated(state, src, dst, t, eids, valid, *, k, directed):
    return _update_impl(state, src, dst, t, eids, valid, k=k, directed=directed)


@partial(jax.jit, static_argnames=("k", "directed"))
def _update_copying(state, src, dst, t, eids, valid, *, k, directed):
    return _update_impl(state, src, dst, t, eids, valid, k=k, directed=directed)


def _update(state, src, dst, t, eids, valid, *, k: int, directed: bool,
            retain: bool = False):
    """Jit'd buffer insert; donates the state on backends that support
    aliasing (donation is a no-op that warns on CPU). Resolved per call so
    importing this module never initializes the JAX backend.

    ``retain=True`` forces the copying variant even off-CPU so references to
    the *pre-update* buffer stay valid — required when the packed buffer is
    exposed to the model step (the fused-attention path reads the state as
    it was when the batch was sampled, predict-then-reveal)."""
    fn = (_update_copying
          if retain or jax.default_backend() == "cpu" else _update_donated)
    return fn(state, src, dst, t, eids, valid, k=k, directed=directed)


@partial(jax.jit, static_argnames=("k",))
def _sample(state, seeds, *, k: int):
    """Gather the K most recent neighbors per seed, most-recent-first."""
    cc = state["cc"][seeds]  # (B, 2) — one gather for cursor and count
    offs = jnp.arange(1, k + 1, dtype=jnp.int32)[None, :]
    raw = cc[:, :1] - offs  # in [-k, k-1]: cheap wrap instead of generic mod
    slots = jnp.where(raw < 0, raw + k, raw)
    rows = state["buf"][seeds[:, None], slots]  # (B, K, 3) — one gather
    mask = jnp.arange(k, dtype=jnp.int32)[None, :] < cc[:, 1:]
    ids = jnp.where(mask, rows[..., 0], -1)
    times = jnp.where(mask, rows[..., 1], 0)
    eids = jnp.where(mask, rows[..., 2], -1)
    return ids, times, eids, mask


class DeviceRecencySampler:
    """JAX device-resident most-recent-K temporal neighbor sampler.

    Drop-in twin of ``RecencySampler``; state lives on ``device`` (default:
    first JAX device) and ``update``/``sample`` run jit-compiled. ``update``
    accepts an optional ``valid`` mask so padded fixed-shape batches compile
    exactly once.
    """

    def __init__(self, num_nodes: int, k: int, directed: bool = False,
                 device=None, retain_state: bool = False):
        if k <= 0:
            raise ValueError("k must be positive")
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self.directed = directed
        self.retain_state = retain_state
        self._device = device or jax.devices()[0]
        self.reset_state()

    def reset_state(self) -> None:
        """Reallocate empty buffers on the target device: ids/eids -1,
        times 0, cursor/count 0 (the packed ``(N+1, K, 3)`` + ``(N+1, 2)``
        layout described in the module docstring)."""
        n, k = self.num_nodes, self.k
        empty = jnp.stack([
            jnp.full((n + 1, k), -1, jnp.int32),   # neighbor ids
            jnp.zeros((n + 1, k), jnp.int32),      # times
            jnp.full((n + 1, k), -1, jnp.int32),   # edge ids
        ], axis=-1)
        self.state = jax.device_put(
            {"buf": empty, "cc": jnp.zeros((n + 1, 2), jnp.int32)},
            self._device,
        )

    @property
    def buffer_ids(self):
        """(N+1, K) neighbor-id rows — the fused attention kernel's input."""
        return self.state["buf"][..., 0]

    @property
    def packed_buffer(self):
        """(N+1, K, 3) packed rows (id, time, edge id) — what
        ``fused_temporal_layer`` consumes. Construct the sampler with
        ``retain_state=True`` if you hold on to this across ``update`` calls
        on a donating (non-CPU) backend."""
        return self.state["buf"]

    # ------------------------------------------------------------------
    _as_i32 = staticmethod(as_int32)

    def update(self, src, dst, t, eids=None, valid=None) -> None:
        """Insert a time-ordered batch of edges into the circular buffers.

        ``src``/``dst``/``t`` are (B,) host or device int arrays; ``eids``
        defaults to -1 (no edge-feature association); ``valid`` is an
        optional (B,) bool mask so fixed-shape padded batches compile once
        (invalid rows are routed to the sink row N and never read).
        """
        src = self._as_i32(src, "src")
        if src.shape[0] == 0:
            return
        if eids is None:
            eids = jnp.full(src.shape, -1, jnp.int32)
        else:
            eids = self._as_i32(eids, "eids")
        if valid is None:
            valid = jnp.ones(src.shape, bool)
        self.state = _update(
            self.state, src, self._as_i32(dst, "dst"),
            self._as_i32(t, "t"), eids,
            jnp.asarray(valid, bool), k=self.k, directed=self.directed,
            retain=self.retain_state,
        )

    def sample(self, seeds, query_t=None) -> NeighborBlock:
        """Gather each seed's (up to) K most recent neighbors on device.

        Returns a fixed-shape ``NeighborBlock`` of (B, K) device arrays,
        most-recent-first, padded with -1 ids / 0 times where a seed has
        fewer than K past neighbors. ``query_t`` (B,) optionally masks
        neighbors newer than each seed's query time (defensive — recency
        state only ever holds past events).
        """
        seeds = jnp.asarray(seeds, jnp.int32)
        ids, times, eids, mask = _sample(self.state, seeds, k=self.k)
        if query_t is not None:
            qt = jnp.asarray(query_t, jnp.int32)[:, None]
            keep = mask & (times <= qt)
            ids = jnp.where(keep, ids, -1)
            times = jnp.where(keep, times, 0)
            eids = jnp.where(keep, eids, -1)
            mask = keep
        return NeighborBlock(ids, times, eids, mask)

    # -- checkpoint contract (shared with RecencySampler) ----------------
    def state_dict(self) -> dict:
        """Canonical host-numpy state ``{ids, times, eids, cursor, count}``
        (int64, sink row stripped) — loads into either recency sampler."""
        host = jax.device_get(self.state)
        buf, cc = host["buf"][:-1], host["cc"][:-1]
        return {
            "ids": buf[..., 0].astype(np.int64),
            "times": buf[..., 1].astype(np.int64),
            "eids": buf[..., 2].astype(np.int64),
            "cursor": cc[:, 0].astype(np.int64),
            "count": cc[:, 1].astype(np.int64),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore buffers saved by either recency sampler (the sink row is
        re-appended and the packed layout rebuilt on device)."""
        def _pad(a, fill):
            a = np.asarray(a)
            pad = np.full((1,) + a.shape[1:], fill, a.dtype)
            return np.concatenate([a, pad]).astype(np.int32)

        buf = np.stack([
            _pad(state["ids"], -1),
            _pad(state["times"], 0),
            _pad(state["eids"], -1),
        ], axis=-1)
        cc = np.stack([_pad(state["cursor"], 0), _pad(state["count"], 0)],
                      axis=-1)
        self.state = jax.device_put(
            {"buf": jnp.asarray(buf), "cc": jnp.asarray(cc)}, self._device
        )
