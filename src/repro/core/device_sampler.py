"""Device-resident recency sampling (the `device_sampling=True` pipeline).

``DeviceRecencySampler`` is the JAX twin of ``RecencySampler``: the per-node
circular buffers (``ids/times/eids`` plus ``cursor``/``count``) live on the
accelerator as a pytree of ``int32`` arrays, and both ``update`` and
``sample`` are jit-compiled pure functions over that pytree. On non-CPU
backends the state argument is donated, so the buffers are updated in place
— no host round-trip and no reallocation per batch.

State layout (chosen from scatter microbenchmarks — XLA scatter cost is per
index row, so the three value channels share one scatter):

  ``buf``: (N+1, K, 3) int32 — channels = (neighbor id, time, edge id)
  ``cc``:  (N+1, 2)    int32 — columns  = (cursor, count)

Row ``N`` is a write sink for dropped/padded events and is never read.
``state_dict`` still speaks the canonical ``ids/times/eids/cursor/count``
contract shared with the host sampler, so checkpoints are interchangeable.

Slot assignment replaces the host-numpy argsort trick with an on-device
segment-cumsum scheme (fixed shapes, one XLA compilation per batch shape):

  1. sort a single fused integer key ``node * m + stream_pos`` — this both
     groups by node and keeps each node's events in stream (= time) order;
  2. per-element sequence number ``seq`` within its node group via a running
     max over group-start positions (cummax = segment cumsum of ones), and
     group multiplicity via a reverse running min over group ends — no
     second scatter;
  3. only the *last K* events of each node survive (sequential semantics
     under wraparound) and every survivor maps to a distinct
     ``(node, (cursor + seq) % K)`` cell, so the packed scatter has no
     meaningful duplicate targets (collisions are confined to the sink row)
     and is bit-deterministic.

Outputs are bit-identical to ``SequentialRecencySampler`` (see
``tests/test_sampler.py`` property tests), including cursor wraparound when
one batch carries more than K events for a node, and duplicate timestamps.

**Multi-device sharding** (``mesh=`` + ``docs/sharding.md``): the buffer is
partitioned row-wise by node id over a 1-D ``jax.sharding.Mesh`` — shard
``s`` owns nodes ``[s*per, (s+1)*per)`` with ``per = ceil(N/shards)`` plus
its *own local sink row*, so the packed global layout is
``(shards*(per+1), K, 3)``. ``update`` and ``sample`` run through
``shard_map``: updates stay shard-local (each shard scatters only the
events of nodes it owns; everything else lands in its local sink), and
``sample`` combines per-shard masked gathers with a single ``psum`` —
exactly one shard owns each seed, so the sum is the owner's value and the
results are bit-identical to the single-device path (property-tested under
``--xla_force_host_platform_device_count=8``). ``state_dict`` always emits
the canonical host layout (sinks and padding stripped), so checkpoints
reshard transparently across mesh sizes in both directions.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampler import NeighborBlock

_SCATTER_KW = dict(unique_indices=True, mode="promise_in_bounds")


def as_int32(a, name: str):
    """Narrow host arrays to int32, loudly rejecting values that would wrap
    (device sampler state is int32; silent truncation would corrupt parity
    with the int64 host samplers). Device arrays pass through untouched —
    no synchronization on hot paths. Shared by both device samplers."""
    if not isinstance(a, jax.Array):
        a = np.asarray(a)
        if a.dtype.itemsize > 4 and a.size and (
                a.max() >= 2**31 or a.min() < -(2**31)):
            raise ValueError(
                f"{name} exceeds int32 range; rescale (e.g. coarser time "
                f"granularity / epoch-relative timestamps) before "
                f"device sampling"
            )
    return jnp.asarray(a, jnp.int32)


def _event_stream(src, dst, t, eids, valid, *, directed: bool):
    """Flatten a batch into the (nodes, ok, vals) insertion stream.

    Directed: one stream position per event (src gets dst). Undirected:
    interleaved src/dst copies (event i -> stream positions 2i, 2i+1) so
    the flattened stream preserves exact sequential insertion order.
    """
    if directed:
        return src, valid, jnp.stack([dst, t, eids], axis=-1)  # (m, 3)
    nodes = jnp.stack([src, dst], 1).reshape(-1)
    ok = jnp.stack([valid, valid], 1).reshape(-1)
    vals = jnp.stack([
        jnp.stack([dst, src], 1).reshape(-1),
        jnp.stack([t, t], 1).reshape(-1),
        jnp.stack([eids, eids], 1).reshape(-1),
    ], axis=-1)
    return nodes, ok, vals


def _insert_stream(state, nodes, ok, vals, *, k: int):
    """Scatter an insertion stream into the circular buffers. Pure/jit.

    ``state``'s last row is the write sink for dropped events (``ok`` False
    or routed off-shard by the sharded caller); results per surviving row
    match sequential insertion exactly. Shared by the single-device update
    (sink = global row N) and the per-shard ``shard_map`` body (sink = the
    shard's local sink row).
    """
    sink = state["cc"].shape[0] - 1  # last row: write target for drops
    m = nodes.shape[0]
    nodes = jnp.where(ok, nodes, sink)
    idx = jnp.arange(m, dtype=jnp.int32)

    # One fused sort key: groups by node, stream order within the group.
    if (sink + 1) * m < 2**31:
        key = nodes * m + idx
        skey = jax.lax.sort(key)
        sn = skey // m
        pos = skey % m
    else:
        # Huge graphs: the fused int32 key would overflow (and int64 is
        # unavailable without jax_enable_x64), so use a stable two-operand
        # sort keyed on the node id with the stream position carried along.
        sn, pos = jax.lax.sort((nodes, idx), is_stable=True, num_keys=1)

    group_start = jnp.concatenate([jnp.ones(1, bool), sn[1:] != sn[:-1]])
    group_end = jnp.concatenate([sn[1:] != sn[:-1], jnp.ones(1, bool)])
    # Segment cumsum of ones: seq[i] = i - (position of i's group head);
    # multiplicity = (position past my group's tail) - head. Both via scans.
    head = jax.lax.associative_scan(
        jnp.maximum, jnp.where(group_start, idx, -1)
    )
    seq = idx - head
    tail = jax.lax.associative_scan(
        jnp.minimum, jnp.where(group_end, idx + 1, m), reverse=True
    )
    mult = tail - head

    # Sequential semantics under wraparound: only the last K events per node
    # are visible afterwards. Earlier ones go to the sink row, where slot
    # collisions are harmless (never read); surviving targets are unique ->
    # the scatter is bit-deterministic.
    survives = (seq >= mult - k) & (sn != sink)
    tgt = jnp.where(survives, sn, sink)
    cur = state["cc"][sn, 0]
    slots = jnp.where(survives, (cur + seq) % k, idx % k)
    buf = state["buf"].at[tgt, slots].set(vals[pos], **_SCATTER_KW)

    # Cursor/count advance by per-node multiplicity; one write per group
    # (group heads), the rest land in the sink row.
    chead = group_start & (sn != sink)
    ctgt = jnp.where(chead, sn, sink)
    ccv = jnp.stack([
        (cur + mult) % k,
        jnp.minimum(state["cc"][sn, 1] + mult, k),
    ], axis=-1)
    cc = state["cc"].at[ctgt].set(ccv, **_SCATTER_KW)
    return {"buf": buf, "cc": cc}


def _update_impl(state, src, dst, t, eids, valid, *, k: int, directed: bool):
    """Insert a time-ordered batch into the circular buffers. Pure/jit."""
    nodes, ok, vals = _event_stream(src, dst, t, eids, valid,
                                    directed=directed)
    return _insert_stream(state, nodes, ok, vals, k=k)


@partial(jax.jit, static_argnames=("k", "directed"), donate_argnums=(0,))
def _update_donated(state, src, dst, t, eids, valid, *, k, directed):
    return _update_impl(state, src, dst, t, eids, valid, k=k, directed=directed)


@partial(jax.jit, static_argnames=("k", "directed"))
def _update_copying(state, src, dst, t, eids, valid, *, k, directed):
    return _update_impl(state, src, dst, t, eids, valid, k=k, directed=directed)


def _update(state, src, dst, t, eids, valid, *, k: int, directed: bool,
            retain: bool = False):
    """Jit'd buffer insert; donates the state on backends that support
    aliasing (donation is a no-op that warns on CPU). Resolved per call so
    importing this module never initializes the JAX backend.

    ``retain=True`` forces the copying variant even off-CPU so references to
    the *pre-update* buffer stay valid — required when the packed buffer is
    exposed to the model step (the fused-attention path reads the state as
    it was when the batch was sampled, predict-then-reveal)."""
    fn = (_update_copying
          if retain or jax.default_backend() == "cpu" else _update_donated)
    return fn(state, src, dst, t, eids, valid, k=k, directed=directed)


def _gather_rows(state, rows_idx, *, k: int):
    """Per-row circular-buffer gather: (rows (B, K, 3), cc (B, 2))."""
    cc = state["cc"][rows_idx]  # (B, 2) — one gather for cursor and count
    offs = jnp.arange(1, k + 1, dtype=jnp.int32)[None, :]
    raw = cc[:, :1] - offs  # in [-k, k-1]: cheap wrap instead of generic mod
    slots = jnp.where(raw < 0, raw + k, raw)
    return state["buf"][rows_idx[:, None], slots], cc


def _finish_sample(rows, cc, *, k: int):
    """Mask gathered rows by per-seed count -> (ids, times, eids, mask)."""
    mask = jnp.arange(k, dtype=jnp.int32)[None, :] < cc[:, 1:]
    ids = jnp.where(mask, rows[..., 0], -1)
    times = jnp.where(mask, rows[..., 1], 0)
    eids = jnp.where(mask, rows[..., 2], -1)
    return ids, times, eids, mask


@partial(jax.jit, static_argnames=("k",))
def _sample(state, seeds, *, k: int):
    """Gather the K most recent neighbors per seed, most-recent-first."""
    rows, cc = _gather_rows(state, seeds, k=k)
    return _finish_sample(rows, cc, k=k)


class DeviceRecencySampler:
    """JAX device-resident most-recent-K temporal neighbor sampler.

    Drop-in twin of ``RecencySampler``; state lives on ``device`` (default:
    first JAX device) and ``update``/``sample`` run jit-compiled. ``update``
    accepts an optional ``valid`` mask so padded fixed-shape batches compile
    exactly once.

    With ``mesh`` (a 1-D ``jax.sharding.Mesh``; see
    ``repro.distributed.sharding.make_node_mesh``) the buffers are
    partitioned row-wise by node id over ``mesh_axis`` and both paths run
    through ``shard_map`` — shard-local scatters for ``update``, a
    psum-combined masked gather for ``sample`` — with outputs bit-identical
    to the single-device path. See the module docstring and
    ``docs/sharding.md`` for the layout and the per-shard sink-row policy.
    """

    def __init__(self, num_nodes: int, k: int, directed: bool = False,
                 device=None, retain_state: bool = False, mesh=None,
                 mesh_axis: str = "data"):
        if k <= 0:
            raise ValueError("k must be positive")
        self.num_nodes = int(num_nodes)
        self.k = int(k)
        self.directed = directed
        self.retain_state = retain_state
        self._mesh = mesh
        self._mesh_axis = mesh_axis
        if mesh is not None:
            from repro.distributed.sharding import (
                node_rows_per_shard,
                replicated_sharding,
                row_sharding,
            )

            if device is not None:
                raise ValueError(
                    "pass either device= or mesh=, not both — a sharded "
                    "sampler's state is placed by the mesh's row sharding "
                    "(docs/sharding.md)"
                )
            if mesh_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh has no axis {mesh_axis!r}; axes are "
                    f"{mesh.axis_names}"
                )
            self._shards = int(mesh.shape[mesh_axis])
            self._per = node_rows_per_shard(self.num_nodes, self._shards)
            self._row_sharding = row_sharding(mesh, mesh_axis)
            self._replicated = replicated_sharding(mesh)
            self._make_sharded_fns()
            self._device = None
        else:
            self._device = device or jax.devices()[0]
        self.reset_state()

    # -- sharded-path machinery ------------------------------------------
    def _make_sharded_fns(self) -> None:
        """Build the per-instance jitted ``shard_map`` update/sample.

        Each shard owns node rows ``[s*per, (s+1)*per)`` plus a local sink
        at local row ``per``; the replicated batch is remapped so owned
        events scatter locally and everything else drops into the local
        sink. ``sample`` gathers per shard, zeroes non-owned rows, and
        psum-combines — exactly one shard owns each seed.
        """
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import SHARD_MAP_KW, shard_map

        mesh, axis = self._mesh, self._mesh_axis
        per, k, directed = self._per, self.k, self.directed
        state_specs = {"buf": P(axis), "cc": P(axis)}
        rep = P()

        def update_body(state, src, dst, t, eids, valid):
            lo = jax.lax.axis_index(axis).astype(jnp.int32) * per
            nodes, ok, vals = _event_stream(src, dst, t, eids, valid,
                                            directed=directed)
            owned = ok & (nodes >= lo) & (nodes < lo + per)
            local = jnp.where(owned, nodes - lo, per)
            return _insert_stream(state, local, owned, vals, k=k)

        def sample_body(state, seeds):
            lo = jax.lax.axis_index(axis).astype(jnp.int32) * per
            owned = (seeds >= lo) & (seeds < lo + per)
            rows, cc = _gather_rows(
                state, jnp.where(owned, seeds - lo, per), k=k)
            rows = jnp.where(owned[:, None, None], rows, 0)
            cc = jnp.where(owned[:, None], cc, 0)
            return (jax.lax.psum(rows, axis), jax.lax.psum(cc, axis))

        upd = shard_map(update_body, mesh=mesh,
                        in_specs=(state_specs, rep, rep, rep, rep, rep),
                        out_specs=state_specs, **SHARD_MAP_KW)
        smp = shard_map(sample_body, mesh=mesh,
                        in_specs=(state_specs, rep), out_specs=(rep, rep),
                        **SHARD_MAP_KW)
        self._sharded_update_donated = jax.jit(upd, donate_argnums=(0,))
        self._sharded_update_copying = jax.jit(upd)
        self._sharded_sample = jax.jit(
            lambda state, seeds: _finish_sample(*smp(state, seeds), k=k))

    def _install_canonical(self, buf: Optional[np.ndarray],
                           cc: Optional[np.ndarray]) -> None:
        """Place canonical ``(N, K, 3)``/``(N, 2)`` host state onto the
        target device(s) (``None`` = empty buffers, sharded mode only —
        the single-device reset builds its empty state directly on device
        and never calls this with ``None``): single-device appends the
        global sink row N; sharded mode materializes each shard's block —
        its node rows plus its local sink row — directly on its device via
        ``jax.make_array_from_callback``, so peak host memory beyond the
        given canonical arrays is one shard's block, never the padded
        global layout (the buffer may not fit one host by design)."""
        n, k = self.num_nodes, self.k
        if self._mesh is None:
            sink_buf = np.zeros((1, k, 3), np.int32)
            sink_buf[..., 0] = -1
            sink_buf[..., 2] = -1
            full_buf = np.concatenate([buf, sink_buf])
            full_cc = np.concatenate([cc, np.zeros((1, 2), np.int32)])
            self.state = jax.device_put(
                {"buf": jnp.asarray(full_buf), "cc": jnp.asarray(full_cc)},
                self._device,
            )
            return
        s, per = self._shards, self._per
        rows_local = per + 1

        def _shard_rows(index):
            """Global row slice -> (shard's first global node id, its
            owned-node count)."""
            shard = (index[0].start or 0) // rows_local
            lo = shard * per
            return lo, max(min(lo + per, n) - lo, 0)

        def cb_buf(index):
            lo, owned = _shard_rows(index)
            out = np.zeros((rows_local, k, 3), np.int32)
            out[..., 0] = -1
            out[..., 2] = -1
            if buf is not None:
                out[:owned] = buf[lo:lo + owned]
            return out

        def cb_cc(index):
            lo, owned = _shard_rows(index)
            out = np.zeros((rows_local, 2), np.int32)
            if cc is not None:
                out[:owned] = cc[lo:lo + owned]
            return out

        self.state = {
            "buf": jax.make_array_from_callback(
                (s * rows_local, k, 3), self._row_sharding, cb_buf),
            "cc": jax.make_array_from_callback(
                (s * rows_local, 2), self._row_sharding, cb_cc),
        }

    def reset_state(self) -> None:
        """Reallocate empty buffers on the target device(s): ids/eids -1,
        times 0, cursor/count 0 (the packed ``(N+1, K, 3)`` + ``(N+1, 2)``
        layout described in the module docstring; sharded mode uses the
        ``(shards*(per+1), ...)`` per-shard-sink layout)."""
        n, k = self.num_nodes, self.k
        if self._mesh is None:
            # Build on device directly — no host-RAM copy of the buffer.
            empty = jnp.stack([
                jnp.full((n + 1, k), -1, jnp.int32),   # neighbor ids
                jnp.zeros((n + 1, k), jnp.int32),      # times
                jnp.full((n + 1, k), -1, jnp.int32),   # edge ids
            ], axis=-1)
            self.state = jax.device_put(
                {"buf": empty, "cc": jnp.zeros((n + 1, 2), jnp.int32)},
                self._device,
            )
            return
        # Sharded: per-shard empty blocks, no full-size host allocation.
        self._install_canonical(None, None)

    @property
    def buffer_ids(self):
        """(rows, K) neighbor-id rows — the fused attention kernel's input.
        Single-device rows = N+1 (global sink last); sharded rows =
        shards*(per+1) with a local sink at local row ``per`` of each shard
        block (see ``rows_per_shard`` / ``docs/sharding.md``)."""
        return self.state["buf"][..., 0]

    @property
    def packed_buffer(self):
        """Packed rows (id, time, edge id) — what ``fused_temporal_layer``
        consumes. Construct the sampler with ``retain_state=True`` if you
        hold on to this across ``update`` calls on a donating (non-CPU)
        backend. Single-device: ``(N+1, K, 3)`` with the global sink at row
        N. Sharded: the ``(shards*(per+1), K, 3)`` per-shard-sink layout,
        ``P(mesh_axis)``-sharded — node ids are *not* direct row indices;
        consume it through ``fused_temporal_layer_sharded`` inside a
        shard_map over ``mesh_axis`` (each shard addresses its block with
        seed-lo-offset local ids; see ``docs/sharding.md``)."""
        return self.state["buf"]

    @property
    def rows_per_shard(self) -> Optional[int]:
        """Node rows owned per shard (``ceil(N/shards)``) in sharded mode;
        ``None`` on a single-device sampler. Each shard's local block in
        ``packed_buffer`` is ``rows_per_shard + 1`` rows (sink last)."""
        return self._per if self._mesh is not None else None

    # ------------------------------------------------------------------
    _as_i32 = staticmethod(as_int32)

    def update(self, src, dst, t, eids=None, valid=None) -> None:
        """Insert a time-ordered batch of edges into the circular buffers.

        ``src``/``dst``/``t`` are (B,) host or device int arrays; ``eids``
        defaults to -1 (no edge-feature association); ``valid`` is an
        optional (B,) bool mask so fixed-shape padded batches compile once
        (invalid rows are routed to the sink row N and never read).
        """
        src = self._as_i32(src, "src")
        if src.shape[0] == 0:
            return
        if eids is None:
            eids = jnp.full(src.shape, -1, jnp.int32)
        else:
            eids = self._as_i32(eids, "eids")
        if valid is None:
            valid = jnp.ones(src.shape, bool)
        dst = self._as_i32(dst, "dst")
        t = self._as_i32(t, "t")
        valid = jnp.asarray(valid, bool)
        if self._mesh is not None:
            # Replicate the batch over the mesh (host arrays and arrays
            # committed to a single device alike), then run the shard_map
            # update — scatters stay shard-local.
            src, dst, t, eids, valid = jax.device_put(
                (src, dst, t, eids, valid), self._replicated)
            fn = (self._sharded_update_copying
                  if self.retain_state or jax.default_backend() == "cpu"
                  else self._sharded_update_donated)
            self.state = fn(self.state, src, dst, t, eids, valid)
            return
        self.state = _update(
            self.state, src, dst, t, eids, valid,
            k=self.k, directed=self.directed, retain=self.retain_state,
        )

    def sample(self, seeds, query_t=None) -> NeighborBlock:
        """Gather each seed's (up to) K most recent neighbors on device.

        Returns a fixed-shape ``NeighborBlock`` of (B, K) device arrays,
        most-recent-first, padded with -1 ids / 0 times where a seed has
        fewer than K past neighbors. ``query_t`` (B,) optionally masks
        neighbors newer than each seed's query time (defensive — recency
        state only ever holds past events).
        """
        seeds = jnp.asarray(seeds, jnp.int32)
        if self._mesh is not None:
            seeds = jax.device_put(seeds, self._replicated)
            ids, times, eids, mask = self._sharded_sample(self.state, seeds)
        else:
            ids, times, eids, mask = _sample(self.state, seeds, k=self.k)
        if query_t is not None:
            qt = jnp.asarray(query_t, jnp.int32)[:, None]
            keep = mask & (times <= qt)
            ids = jnp.where(keep, ids, -1)
            times = jnp.where(keep, times, 0)
            eids = jnp.where(keep, eids, -1)
            mask = keep
        return NeighborBlock(ids, times, eids, mask)

    # -- checkpoint contract (shared with RecencySampler) ----------------
    def state_dict(self) -> dict:
        """Canonical host-numpy state ``{ids, times, eids, cursor, count}``
        (int64, sink row(s) and shard padding stripped) — loads into either
        recency sampler, at any mesh size (resharding happens on load)."""
        n, k = self.num_nodes, self.k
        host = jax.device_get(self.state)
        if self._mesh is None:
            buf, cc = host["buf"][:-1], host["cc"][:-1]
        else:
            # Strip each shard's local sink row, re-concatenate the node
            # rows in id order, and drop the last shard's padding rows.
            s, per = self._shards, self._per
            buf = host["buf"].reshape(s, per + 1, k, 3)[:, :per]
            buf = buf.reshape(s * per, k, 3)[:n]
            cc = host["cc"].reshape(s, per + 1, 2)[:, :per]
            cc = cc.reshape(s * per, 2)[:n]
        return {
            "ids": buf[..., 0].astype(np.int64),
            "times": buf[..., 1].astype(np.int64),
            "eids": buf[..., 2].astype(np.int64),
            "cursor": cc[:, 0].astype(np.int64),
            "count": cc[:, 1].astype(np.int64),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore buffers saved by either recency sampler at any mesh
        size (the canonical host layout is re-packed for this sampler's
        sink/shard layout and placed on the target device(s))."""
        buf = np.stack([
            np.asarray(state["ids"]),
            np.asarray(state["times"]),
            np.asarray(state["eids"]),
        ], axis=-1).astype(np.int32)
        cc = np.stack([np.asarray(state["cursor"]),
                       np.asarray(state["count"])], axis=-1).astype(np.int32)
        self._install_canonical(buf, cc)
