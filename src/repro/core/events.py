"""Event types: the fundamental unit of a temporal graph (paper Def. 3.1).

An *edge event* ``(t, src, dst, x_edge)`` is a timestamped interaction; a
*node event* ``(t, node, x_node)`` is the arrival of new features at a node.
Storage keeps events in struct-of-arrays COO form (see ``graph.py``); these
dataclasses are the scalar views used at API boundaries and in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EdgeEvent:
    t: int
    src: int
    dst: int
    features: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class NodeEvent:
    t: int
    node: int
    features: Optional[np.ndarray] = None
