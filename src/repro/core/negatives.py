"""Negative edge construction for dynamic link prediction.

Supports the standard protocols:
  * random   — uniform destination corruption (training default)
  * historical — negatives drawn from previously-seen edges not active now
                 (Poursafaei et al. 2022 evaluation)
  * one-vs-many — TGB-style: each positive is ranked against a fixed set of
                 ``num_negatives`` sampled destinations (deterministic per
                 batch, seeded), enabling MRR computation.

``snapshot_negatives`` is the DTDG counterpart: per-snapshot corrupted
destinations as a pure function of ``(seed, num_negatives, snapshot row)``,
so the scan-compiled epoch (which pre-draws every snapshot's negatives in
one call) and the per-snapshot hook path (``SnapshotNegativeHook``) produce
bit-identical draws. See ``docs/dtdg.md``.
"""

from __future__ import annotations

from typing import Optional, Set, Tuple

import numpy as np


def snapshot_negatives(seed: int, num_nodes: int, capacity: int,
                       num_negatives: int, rows):
    """Deterministic per-snapshot negative destinations, device-resident.

    Returns a ``(len(rows), capacity, num_negatives)`` int32 JAX array of
    uniform node draws. Row ``r``'s draws depend only on
    ``(seed, num_negatives, r)`` — a counter-derived ``fold_in`` chain — so
    any contiguous or scattered subset of rows reproduces exactly the same
    negatives as a bulk draw over all rows (the scan-vs-loop parity
    invariant), and resuming from a checkpointed snapshot cursor replays the
    stream bit-identically.
    """
    import jax
    import jax.numpy as jnp

    key = jax.random.fold_in(jax.random.PRNGKey(seed), num_negatives)

    def one(row):
        return jax.random.randint(
            jax.random.fold_in(key, row), (capacity, num_negatives),
            0, max(int(num_nodes), 1), jnp.int32,
        )

    return jax.vmap(one)(jnp.asarray(rows, jnp.int32))


class NegativeEdgeSampler:
    """Stateful negative-edge sampler for the CTDG link recipes (random or
    historical destination corruption; see the module docstring)."""

    def __init__(
        self,
        num_nodes: int,
        strategy: str = "random",
        num_negatives: int = 1,
        seed: int = 0,
        dst_pool: Optional[np.ndarray] = None,
    ):
        if strategy not in ("random", "historical"):
            raise ValueError(f"unknown strategy {strategy!r}")
        self.num_nodes = int(num_nodes)
        self.strategy = strategy
        self.num_negatives = int(num_negatives)
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        # Destination pool (e.g. item side of a bipartite graph).
        self.dst_pool = (
            np.arange(self.num_nodes, dtype=np.int64)
            if dst_pool is None
            else np.asarray(dst_pool, dtype=np.int64)
        )
        self._hist: Set[Tuple[int, int]] = set()
        self._hist_dst = np.zeros(0, dtype=np.int64)
        self._hist_dirty = False

    def reset_state(self) -> None:
        """Reset the RNG and the historical destination pool."""
        self._rng = np.random.default_rng(self._seed)
        self._hist.clear()
        self._hist_dst = np.zeros(0, dtype=np.int64)
        self._hist_dirty = False

    def observe(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Record positives for the historical strategy."""
        if self.strategy != "historical":
            return
        for u, v in zip(src.tolist(), dst.tolist()):
            self._hist.add((u, v))
        self._hist_dirty = True

    def sample(self, src: np.ndarray, dst: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Sample ``(B, num_negatives)`` negative destinations."""
        B = len(src)
        if self.strategy == "random" or not self._hist:
            neg = self._rng.choice(self.dst_pool, size=(B, self.num_negatives))
            return neg.astype(np.int64)
        # historical: half historical destinations, half random (the standard
        # mixed protocol); vectorized draw from the historical dst multiset.
        if self._hist_dirty:
            self._hist_dst = np.fromiter(
                (v for (_, v) in self._hist), dtype=np.int64, count=len(self._hist)
            )
            self._hist_dirty = False
        n_hist = self.num_negatives // 2
        n_rand = self.num_negatives - n_hist
        parts = []
        if n_hist:
            parts.append(self._rng.choice(self._hist_dst, size=(B, n_hist)))
        if n_rand:
            parts.append(self._rng.choice(self.dst_pool, size=(B, n_rand)))
        return np.concatenate(parts, axis=1).astype(np.int64)
