#!/usr/bin/env python
"""Docs link checker: every relative markdown link in README.md and docs/
must resolve to a real file or directory, so the docs can't rot silently.

Usage: python scripts/check_doc_links.py   (exits non-zero on broken links)

Checks ``[text](target)`` markdown links, skipping absolute URLs
(http/https/mailto) and pure in-page anchors; a ``path#anchor`` target is
checked for the path part only. Shared with ``tests/test_docs.py`` so the
same rule gates both CI step and tier-1.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path):
    """README.md plus every markdown file under docs/."""
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def broken_links(root: Path):
    """Return [(file, target), ...] for every unresolvable relative link."""
    bad = []
    for f in doc_files(root):
        for target in LINK_RE.findall(f.read_text()):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (f.parent / path).exists():
                bad.append((str(f.relative_to(root)), target))
    return bad


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    bad = broken_links(root)
    for f, target in bad:
        print(f"BROKEN LINK {f}: ({target})", file=sys.stderr)
    files = doc_files(root)
    print(f"checked {len(files)} markdown files, {len(bad)} broken links")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
