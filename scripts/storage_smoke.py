#!/usr/bin/env python
"""Out-of-core storage smoke (CI): convert a synthetic ~1M-edge stream to
an ``MmapStore`` without ever materializing it, train one windowed CTDG
link epoch straight off the store, and assert the epoch's peak-RSS delta
stays a small fraction of the stream size (``resource.getrusage``) — the
acceptance check for ``docs/storage.md``'s RAM-budget claim.

A small-prefix parity phase first trains/evaluates the same experiment on
both backends and asserts loss and MRR are bit-identical, so the big epoch
is exercising the exact code path the parity proof covers.

Usage:
    PYTHONPATH=src python scripts/storage_smoke.py [--edges 1000000]
        [--d-edge 64] [--batch-size 10000] [--rss-frac 0.5]
"""

from __future__ import annotations

import argparse
import resource
import shutil
import sys
import tempfile

import numpy as np


def stream_chunks(n_edges: int, d_edge: int, num_nodes: int,
                  chunk: int = 1 << 16, seed: int = 0):
    """Time-sorted synthetic chunks; only one chunk is ever resident."""
    rng = np.random.default_rng(seed)
    t0 = 0
    for lo in range(0, n_edges, chunk):
        m = min(chunk, n_edges - lo)
        yield {
            "src": rng.integers(0, num_nodes, m),
            "dst": rng.integers(0, num_nodes, m),
            "t": t0 + np.sort(rng.integers(0, 1000, m)),
            "edge_feats": rng.standard_normal((m, d_edge)).astype(np.float32),
        }
        t0 += 1000


def rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=1_000_000)
    ap.add_argument("--d-edge", type=int, default=64)
    ap.add_argument("--num-nodes", type=int, default=20_000)
    ap.add_argument("--batch-size", type=int, default=20_000)
    ap.add_argument("--parity-edges", type=int, default=20_000)
    ap.add_argument("--rss-slack-mb", type=float, default=200.0,
                    help="fixed budget for jit compile + step activations "
                         "(stream-size independent)")
    ap.add_argument("--rss-frac", type=float, default=0.25,
                    help="stream-proportional part of the epoch peak-RSS "
                         "budget: released mmap pages must keep the "
                         "stream's resident share under this fraction")
    a = ap.parse_args()

    from repro.storage import MmapStore
    from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, TrainSpec

    tmp = tempfile.mkdtemp(prefix="storage_smoke_")
    try:
        path = f"{tmp}/store"
        store = MmapStore.from_chunks(
            path, stream_chunks(a.edges, a.d_edge, a.num_nodes))
        stream_mb = (a.edges * (3 * 8 + 4 * a.d_edge)) / 2**20
        print(f"converted {a.edges} edges (d={a.d_edge}, "
              f"{stream_mb:.0f}MB on disk) -> {path}  "
              f"rss={rss_mb():.0f}MB")

        exp = Experiment(
            model=ModelSpec("graphmixer",
                            {"d_model": 32, "d_time": 16, "num_layers": 1,
                             "channel_expansion": 2.0}),
            sampler=SamplerSpec(kind="recency", k=4),
            train=TrainSpec(batch_size=a.batch_size, eval_negatives=5,
                            seed=0),
        )

        # Parity phase: first --parity-edges events on each backend must
        # produce bit-identical loss and MRR (also warms the jit caches
        # for the shapes the big epoch reuses).
        prefix = store.to_data().slice_events(0, a.parity_edges)
        pre_path = f"{tmp}/prefix"
        MmapStore.from_data(pre_path, prefix)

        def run(d):
            pipe = exp.compile(d)
            loss, _ = pipe.train_epoch()
            mrr, _ = pipe.evaluate("val")
            return loss, mrr

        l_mem, m_mem = run(prefix.to_store())
        l_mm, m_mm = run(MmapStore(pre_path))
        print(f"parity ({a.parity_edges} edges): "
              f"inmem loss={l_mem:.6f} mrr={m_mem:.4f} | "
              f"mmap loss={l_mm:.6f} mrr={m_mm:.4f}")
        assert l_mem == l_mm, "backend loss parity FAILED"
        assert m_mem == m_mm, "backend MRR parity FAILED"

        # Out-of-core phase: one windowed epoch over the full stream off
        # the mmap store; pages are released after every batch, so the
        # epoch's peak-RSS delta must stay well under the stream size.
        pipe = exp.compile(MmapStore(path))
        rss0 = rss_mb()
        loss, secs = pipe.train_epoch()
        delta = rss_mb() - rss0
        # Fixed slack covers the stream-size-independent costs (jit
        # compile, step activations, hook state); the proportional term is
        # the actual out-of-core claim — with release() after every batch
        # the stream's resident share must stay a small fraction of its
        # size. A regression that materializes the full stream adds
        # ~stream_mb to the delta and trips the gate.
        budget = a.rss_slack_mb + a.rss_frac * stream_mb
        eps = pipe.train_data.num_edge_events / secs
        print(f"epoch off MmapStore: loss={loss:.6f} "
              f"({eps:,.0f} events/s)  rss_delta={delta:.0f}MB "
              f"budget={budget:.0f}MB")
        assert delta < budget, (
            f"epoch peak-RSS delta {delta:.0f}MB exceeds budget "
            f"{budget:.0f}MB ({a.rss_slack_mb:.0f}MB slack + "
            f"{a.rss_frac} x {stream_mb:.0f}MB stream)")
        print("storage smoke OK")
        return 0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
