"""Produce a demo telemetry JSONL: one tiny CTDG link epoch + eval wired
through ``TrainSpec.telemetry`` — the artifact CI uploads and renders into
the job summary (``scripts/render_telemetry_summary.py``).

Usage: ``PYTHONPATH=src python scripts/telemetry_demo.py [out.jsonl]``

Every line is validated against the ``repro.obs.records`` schema before
the script exits 0, so the uploaded artifact is guaranteed parseable.
"""

from __future__ import annotations

import json
import sys


def main(out: str = "telemetry.jsonl") -> None:
    """Run the demo epoch and write (validated) records to ``out``."""
    from repro.obs import device_memory_gauges, validate
    from repro.tg import DataSpec, Experiment, ModelSpec, SamplerSpec, \
        TrainSpec

    exp = Experiment(
        data=DataSpec("tiny", scale=1.0),
        model=ModelSpec("tgat", {"num_layers": 1}),
        sampler=SamplerSpec(k=4),
        train=TrainSpec(batch_size=100, epochs=1, eval_every=1,
                        telemetry=out),
    )
    result = exp.run(splits=("val",))
    # Flush aggregates (counters/gauges/hists) into the file and record
    # device memory, exercising the gauge path on whatever backend CI has.
    tel = result["pipeline"].telemetry
    device_memory_gauges(tel)
    tel.flush()

    records = [json.loads(ln) for ln in open(out)]
    for r in records:
        validate(r)
    kinds = sorted({r["kind"] for r in records})
    print(f"{out}: {len(records)} records, kinds={kinds}, "
          f"val MRR={result['metrics']['val']:.4f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
