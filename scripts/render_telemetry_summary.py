"""Render a telemetry JSONL into a GitHub-flavored markdown summary.

Usage::

    python scripts/render_telemetry_summary.py telemetry.jsonl >> "$GITHUB_STEP_SUMMARY"

Prints the per-phase span timing table (``repro.obs.span_report`` in
markdown mode) plus a short counter/histogram digest — the CI job summary
a reviewer reads instead of downloading the artifact.
"""

from __future__ import annotations

import json
import sys


def render(path: str) -> str:
    """The markdown summary for the JSONL file at ``path``."""
    sys.path.insert(0, "src")
    from repro.obs import span_report

    records = [json.loads(ln) for ln in open(path)]
    out = ["### Telemetry: per-phase timing", "",
           span_report(records, min_pct=0.0, markdown=True), ""]
    counters = [r for r in records if r["kind"] == "counter"]
    hists = [r for r in records if r["kind"] == "hist"]
    if counters:
        out += ["### Counters", "", "| counter | value |", "| --- | ---: |"]
        out += [f"| {r['name']} | {r['value']:g} |" for r in counters]
        out.append("")
    if hists:
        out += ["### Latency histograms", "",
                "| histogram | count | p50 (s) | p99 (s) |",
                "| --- | ---: | ---: | ---: |"]
        out += [f"| {r['name']} | {r['count']} | {r['p50']:.2e} "
                f"| {r['p99']:.2e} |" for r in hists]
        out.append("")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else "telemetry.jsonl"))
