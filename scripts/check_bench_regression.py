#!/usr/bin/env python
"""Bench-regression gate: compare a CI ``bench.jsonl`` trajectory against
the committed ``benchmarks/baseline_cpu.json``.

For every benchmark name present in both files, the current per-name median
``us`` is compared to the baseline median; a ratio above the tolerance
fails the gate (exit 1). Comparisons are regime-aware: points are grouped
by the (backend, device_count) metadata every BENCH_JSON record carries,
and a current point is only gated against a baseline entry measured under
the *same* regime — an 8-emulated-device median vs a 1-device baseline is
reported as skipped, never as a pass or regression. Benchmarks only in the
current run are reported as "new" (no gate — add them to the baseline when
they stabilize); baseline entries missing from the current run are skipped
(the tier-1 and multi-device jobs each run different subsets against one
shared baseline).

A markdown trajectory table is printed to stdout and, when the
``GITHUB_STEP_SUMMARY`` env var is set (GitHub Actions), appended to the
job's step summary.

Direction: baseline entries default to lower-is-better (latencies). An
entry with ``"direction": "higher"`` (rates, e.g. the serving bench's
requests/s) inverts the gate — a regression is the current value falling
below baseline/tolerance. Both ``direction`` and per-bench ``tolerance``
survive ``--write-baseline`` refreshes.

Tolerance resolution (first match wins): per-bench ``tolerance`` in the
baseline file, then ``--tolerance`` (default 1.5x). CI passes an explicit
wider tolerance while the committed baseline comes from a different
machine class than the runners; tighten it once the baseline is refreshed
from a runner-produced artifact.

Usage:
    python scripts/check_bench_regression.py \
        [--bench bench.jsonl] [--baseline benchmarks/baseline_cpu.json] \
        [--tolerance 1.5]

Refreshing the baseline:
    python scripts/check_bench_regression.py --write-baseline bench.jsonl
rewrites ``--baseline`` from a bench.jsonl's per-name medians.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
from typing import Dict, List


def read_bench(path: str) -> Dict[str, List[dict]]:
    """Aggregate a bench.jsonl into per-name regime entries.

    Points are grouped by (name, backend, device_count) — the metadata
    ``benchmarks/common.py`` stamps on every record — so a trajectory file
    spanning device regimes (e.g. a 1-device and an 8-device run of the
    same bench) is never pooled into one meaningless median.
    """
    by_key: Dict[tuple, List[float]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            key = (rec["name"], rec.get("backend"), rec.get("device_count"))
            by_key.setdefault(key, []).append(float(rec["us"]))
    out: Dict[str, List[dict]] = {}
    for (name, backend, devices), vals in by_key.items():
        out.setdefault(name, []).append({
            "us": statistics.median(vals), "runs": len(vals),
            "backend": backend, "device_count": devices,
        })
    return out


def _regime(entry: dict) -> tuple:
    return entry.get("backend"), entry.get("device_count")


def _regime_label(entry: dict) -> str:
    return f"{entry.get('backend') or '?'}x{entry.get('device_count') or '?'}"


def compare(current: Dict[str, List[dict]], baseline: Dict[str, dict],
            tolerance: float):
    """Per-name comparison rows + the list of regressions.

    Only current entries whose (backend, device_count) regime matches the
    baseline entry's recorded regime are gated; same-named points from a
    different regime are reported but never compared (a 1-device median vs
    an 8-device baseline is not a regression signal).
    """
    rows, regressions = [], []
    for name in sorted(set(current) | set(baseline)):
        curs, base = current.get(name, []), baseline.get(name)
        if base is None:
            for c in curs:
                rows.append((name, None, c["us"], None, "new (no baseline)"))
            continue
        if not curs:
            rows.append((name, base["us"], None, None, "not run"))
            continue
        for c in curs:
            if _regime(c) != _regime(base):
                rows.append((name, base["us"], c["us"], None,
                             f"skipped (regime {_regime_label(c)} != "
                             f"baseline {_regime_label(base)})"))
                continue
            tol = float(base.get("tolerance") or tolerance)
            ratio = c["us"] / base["us"] if base["us"] else float("inf")
            # direction "lower" (default: latencies) regresses when the
            # ratio grows; "higher" (rates, e.g. requests/s) when it
            # shrinks below 1/tolerance.
            if base.get("direction") == "higher":
                regressed = ratio < 1.0 / tol
                limit = f"< {1.0 / tol:.2f}x"
            else:
                regressed = ratio > tol
                limit = f"> {tol:.2f}x"
            if regressed:
                status = f"REGRESSION ({limit})"
                regressions.append((name, ratio, tol))
            else:
                status = "ok"
            rows.append((name, base["us"], c["us"], ratio, status))
    return rows, regressions


def format_table(rows) -> str:
    """Markdown trajectory table for stdout / the GitHub step summary."""
    out = ["| benchmark | baseline us | current us | ratio | status |",
           "|---|---:|---:|---:|---|"]
    for name, base, cur, ratio, status in rows:
        base_s = "-" if base is None else f"{base:.1f}"
        cur_s = "-" if cur is None else f"{cur:.1f}"
        ratio_s = "-" if ratio is None else f"{ratio:.2f}x"
        out.append(f"| {name} | {base_s} | {cur_s} | {ratio_s} | {status} |")
    return "\n".join(out)


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="bench.jsonl",
                    help="bench.jsonl produced by the CI bench steps")
    ap.add_argument("--baseline", default="benchmarks/baseline_cpu.json")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="max allowed current/baseline ratio (default 1.5)")
    ap.add_argument("--write-baseline", metavar="BENCH_JSONL",
                    help="rewrite --baseline from this bench.jsonl and exit")
    args = ap.parse_args(argv)

    if args.write_baseline:
        benches = read_bench(args.write_baseline)
        multi = sorted(n for n, entries in benches.items()
                       if len(entries) > 1)
        if multi:
            print(f"refusing to write baseline: {args.write_baseline} has "
                  f"multiple device regimes for {multi}; the baseline keys "
                  f"one regime per bench name — refresh from single-regime "
                  f"files", file=sys.stderr)
            return 1
        # Carry per-bench tolerance and direction overrides through a
        # refresh — they are first-priority gate inputs and must survive
        # rewrites.
        old_tol, old_dir = {}, {}
        if os.path.exists(args.baseline):
            with open(args.baseline) as f:
                old = json.load(f).get("benches", {})
            old_tol = {n: v["tolerance"] for n, v in old.items()
                       if v.get("tolerance")}
            old_dir = {n: v["direction"] for n, v in old.items()
                       if v.get("direction")}
        payload = {
            "note": "per-bench median us (one device regime per name); "
                    "refresh via scripts/check_bench_regression.py "
                    "--write-baseline",
            "benches": {n: {"us": round(e[0]["us"], 1), "runs": e[0]["runs"],
                            "backend": e[0]["backend"],
                            "device_count": e[0]["device_count"],
                            **({"tolerance": old_tol[n]} if n in old_tol
                               else {}),
                            **({"direction": old_dir[n]} if n in old_dir
                               else {})}
                        for n, e in sorted(benches.items())},
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(benches)} baseline entries to {args.baseline}")
        return 0

    current = read_bench(args.bench)
    with open(args.baseline) as f:
        baseline = json.load(f)["benches"]
    rows, regressions = compare(current, baseline, args.tolerance)
    table = format_table(rows)
    print(table)

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write("## Bench trajectory vs committed baseline\n\n")
            f.write(table + "\n")

    if regressions:
        print("\nFAIL: bench regressions detected:", file=sys.stderr)
        for name, ratio, tol in regressions:
            print(f"  {name}: {ratio:.2f}x baseline (tolerance {tol:.2f}x)",
                  file=sys.stderr)
        return 1
    print(f"\nOK: {sum(1 for r in rows if r[4] == 'ok')} benches within "
          f"tolerance, {sum(1 for r in rows if r[4].startswith('new'))} new, "
          f"{sum(1 for r in rows if r[4] == 'not run')} not run, "
          f"{sum(1 for r in rows if r[4].startswith('skipped'))} skipped "
          f"(regime mismatch)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
